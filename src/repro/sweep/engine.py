"""Campaign execution: expand → (pool of workers) → trace store.

Every sweep point runs the analytical pipeline (lower + compile + HLO walk
→ three-term bounds) and, when ``measured``, executes the *same* compiled
executable to fold wall time back in (``repro.trace``).  One
schema-versioned :class:`~repro.trace.store.TraceRecord` per point lands in
the store, stamped with the sweep name and the point's content hash — the
report side groups on those.

Two mechanics matter here:

* **Process-pool workers.**  XLA's host-platform device count is fixed at
  jax import, so a point whose mesh needs N > 1 (virtual) devices cannot
  run in a process that already imported jax.  The engine groups points by
  device count and runs one spawn-context pool per group; each worker's
  initializer sets ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
  *before* the first job triggers the lazy jax import.  This module
  therefore imports neither jax nor ``repro.trace`` at module scope.

* **Per-point analysis caching.**  Analytical (bound-only) points are pure
  functions of (point, jax version, machine model): their phase payloads
  are cached under the point key, so re-running a campaign recompiles only
  points whose spec actually changed.  Measured points always execute —
  a wall-clock sample is not cacheable — but still share the store schema.

Campaign resilience (docs/DESIGN.md §17) is layered on top: workers run
under a :class:`~repro.resilience.watchdog.SupervisedPool` so a point that
hangs past ``deadline_s`` is killed and its worker replaced; failed points
retry with exponential backoff and are quarantined after ``retries + 1``
attempts; every lifecycle event lands fsync'd in the campaign journal
(``sweep_journal.jsonl`` beside the store) so ``--resume`` can skip every
point whose record already landed — across any number of crashes.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
import traceback
from typing import Any, Callable, Mapping

from repro.resilience import faults
from repro.resilience.journal import CampaignJournal, journal_path_for
from repro.resilience.watchdog import SupervisedPool
from repro.session.workspace import (LEGACY_SWEEP_CACHE, LEGACY_SWEEP_STORE,
                                     resolve_sweep_cache,
                                     resolve_sweep_store)
from repro.sweep.spec import SweepPoint, SweepSpec

# legacy constants (pre-workspace callers import them); the engine itself
# resolves through repro.session.workspace so REPRO_WORKSPACE governs it
DEFAULT_STORE = LEGACY_SWEEP_STORE
DEFAULT_CACHE_DIR = LEGACY_SWEEP_CACHE


@dataclasses.dataclass
class PointResult:
    """Outcome of one point: a stored record id, or a failure."""

    point: SweepPoint
    run_id: str | None = None
    error: str | None = None
    cached: bool = False
    wall_s: float = 0.0             # total measured step time (0 = analytical)
    attempts: int = 1
    quarantined: bool = False       # exhausted its attempts this campaign
    resumed: bool = False           # skipped: record landed in a prior run

    @property
    def ok(self) -> bool:
        return self.error is None


@dataclasses.dataclass
class SweepResult:
    results: list[PointResult]
    skipped: list[tuple[SweepPoint, str]]

    @property
    def n_ok(self) -> int:
        return sum(1 for r in self.results if r.ok)

    @property
    def n_failed(self) -> int:
        return sum(1 for r in self.results if not r.ok)

    @property
    def n_cached(self) -> int:
        return sum(1 for r in self.results if r.cached)

    @property
    def n_quarantined(self) -> int:
        return sum(1 for r in self.results if r.quarantined)

    @property
    def n_resumed(self) -> int:
        return sum(1 for r in self.results if r.resumed)

    def failure_summary(self) -> list[str]:
        """One line per failed point: label, attempts, last error line —
        the operator-facing digest (full tracebacks stay in the journal)."""
        out = []
        for r in self.results:
            if r.ok:
                continue
            lines = [ln for ln in (r.error or "").splitlines() if ln.strip()]
            last = lines[-1].strip() if lines else "unknown error"
            tag = "quarantined" if r.quarantined else "failed"
            out.append(f"{r.point.label}: {tag} after {r.attempts} "
                       f"attempt(s) — {last}")
        return out


# --------------------------------------------------------------------------
# One point, in-process (lazy jax import — workers set XLA_FLAGS first)
# --------------------------------------------------------------------------

def _point_run(point: SweepPoint):
    from repro.configs.base import RunConfig
    return RunConfig(amp=point.amp, fusion=point.fusion)


def _build_point(point: SweepPoint):
    """(model, run, phases, shardings | None, mesh | None) for one point."""
    import jax

    from repro.configs.registry import get_config, get_smoke
    from repro.models import api as M
    from repro.trace.cli import build_phase_args

    cfg = get_smoke(point.config) if point.smoke else get_config(point.config)
    run = _point_run(point)
    model = M.build(cfg)
    phases = build_phase_args(model, run, seq=point.seq, batch=point.batch,
                              concrete=point.measured)
    if point.n_devices == 1:
        return model, run, phases, None, None

    if jax.device_count() < point.n_devices:
        raise RuntimeError(
            f"{point.label}: needs {point.n_devices} devices but this "
            f"process has {jax.device_count()} — run through the sweep "
            "engine's worker pool (it sets the XLA host-device count), "
            "not inline")
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_mesh
    mesh = make_mesh(point.mesh, ("data", "model"))
    pshard = shd.param_shardings(model.spec, mesh, run)
    shardings: dict[str, tuple] = {}
    for name, (_fn, args) in phases.items():
        if name == "opt":
            params, grads, opt_state = args
            oshard = shd.opt_state_shardings(opt_state, pshard, mesh)
            shardings[name] = (pshard, pshard, oshard)
        else:
            _params, batch = args
            shardings[name] = (pshard,
                               shd.shard_batch_dim(batch, mesh, run))
    return model, run, phases, shardings, mesh


def _matmul_class(run) -> str | None:
    """AMP policy → assumed dot/conv ceiling class (docs/DESIGN.md §9)."""
    import jax.numpy as jnp
    return "bf16" if run.compute_dtype == jnp.bfloat16 else None


def _resolve_machine(name: str):
    """The machine model every bound in this point is computed against:
    the registry spec with stored empirical interconnect ceilings folded
    in when ``repro.net characterize`` has run for this machine key
    (docs/DESIGN.md §18) — datasheet interconnect otherwise."""
    from repro.net.characterize import machine_with_net
    return machine_with_net(name)


def _analytical_payload(res, machine) -> dict[str, Any]:
    """Phase payload for a bound-only point: a zero-wall measurement, so
    the schema (and serializer) is exactly ``trace.store.phase_payload``
    — measured fields come out zero, the envelope/bound fields are the
    record's substance."""
    from repro.trace.collector import measurement_from_profile
    from repro.trace.store import phase_payload
    res.wall_s = 0.0
    m = measurement_from_profile(res, machine)
    # zero wall attributes zero time everywhere: rank by bound instead so
    # the persisted top-kernel slice is the analytically hottest ones
    m.kernels.sort(key=lambda k: -k.bound_s)
    return phase_payload(m)


def _cache_path(cache_dir: str, point: SweepPoint) -> str:
    import jax

    from repro.configs.registry import get_config, get_smoke
    from repro.core.machine import MACHINES
    # an analytical payload is a pure function of the point spec, the
    # resolved config constants, the machine-model constants, and the jax
    # version (lowering changes move the bounds) — hash all four so an
    # edited config or MachineSpec invalidates instead of serving stale
    # bounds
    cfg = get_smoke(point.config) if point.smoke else get_config(point.config)
    machine = MACHINES.get(point.machine)
    if machine is not None:
        # hash the *resolved* model (empirical net ceilings folded in):
        # a fresh `repro net characterize` moves the collective bounds,
        # so it must invalidate analytical payloads too
        machine = _resolve_machine(point.machine)
    env = json.dumps({
        "config": dataclasses.asdict(cfg),
        "machine": dataclasses.asdict(machine) if machine else point.machine,
        "jax": jax.__version__,
    }, sort_keys=True, default=str)
    tag = f"{point.key}-{hashlib.sha256(env.encode()).hexdigest()[:16]}"
    return os.path.join(cache_dir, f"{tag}.json")


def _cache_load(cache_dir: str | None,
                point: SweepPoint) -> dict[str, Any] | None:
    if not cache_dir:
        return None
    path = _cache_path(cache_dir, point)
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def _cache_save(cache_dir: str | None, point: SweepPoint,
                phases: Mapping[str, Any]) -> None:
    if not cache_dir:
        return
    os.makedirs(cache_dir, exist_ok=True)
    path = _cache_path(cache_dir, point)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(dict(phases), f)
    os.replace(tmp, path)


def run_point(point: SweepPoint, *, iters: int = 3, warmup: int = 1,
              cache_dir: str | None = None,
              sweep_name: str | None = None):
    """Execute one sweep point in this process → ``(TraceRecord, cached)``.

    Measured points compile once, analyze + execute that executable
    (``repro.trace.collector``); analytical points only lower/compile/
    analyze — and skip even that on a cache hit.
    """
    from repro.trace.store import record_from_payloads, record_from_phases

    mesh_dict = {"data": point.mesh[0], "model": point.mesh[1]}
    meta = {"sweep_point": point.key, "sweep": sweep_name or "adhoc",
            "label": point.label, **point.to_dict()}
    # interconnect-ceiling provenance: which measured roofs (if any) the
    # collective bounds in this record were computed against
    from repro.net.characterize import net_ceilings
    nc = net_ceilings(point.machine)
    if nc:
        meta["net_ceilings"] = nc
    if point.measured:
        # which kernel configs this measurement will run with (tuned
        # winners vs hardcoded defaults) — the report side flags points
        # measured with defaults after a tuned winner exists
        from repro.tune import active_dispatch_table, active_kernel_configs
        meta["kernel_configs"] = active_kernel_configs()
        meta["dispatch_table"] = active_dispatch_table(machine=point.machine)

    if not point.measured:
        cached = _cache_load(cache_dir, point)
        if cached is not None:
            return record_from_payloads(
                point.config, cached, machine=point.machine, mesh=mesh_dict,
                meta={**meta, "cached": True}), True

        from repro.core.profiler import profile_fn
        model, run, phases, shardings, mesh = _build_point(point)
        machine = _resolve_machine(point.machine)
        payloads = {}
        for name, (fn, args) in phases.items():
            res = profile_fn(
                fn, args=args, name=name, machine=machine, mesh=mesh,
                in_shardings=shardings[name] if shardings else None,
                matmul_class=_matmul_class(run))
            payloads[name] = _analytical_payload(res, machine)
        _cache_save(cache_dir, point, payloads)
        return record_from_payloads(
            point.config, payloads, machine=point.machine, mesh=mesh_dict,
            meta={**meta, "cached": False}), False

    # measured: compile once, analyze + execute the same executable
    import jax

    from repro.trace.collector import collect_phase
    model, run, phases, shardings, mesh = _build_point(point)
    ms = {}
    for name, (fn, args) in phases.items():
        in_sh = shardings[name] if shardings else None
        concrete = args
        if mesh is not None:
            concrete = jax.device_put(args, in_sh)
        ms[name] = collect_phase(
            name, fn, args, machine=_resolve_machine(point.machine),
            iters=iters,
            warmup=warmup, concrete_args=concrete, mesh=mesh,
            in_shardings=in_sh, matmul_class=_matmul_class(run))
    return record_from_phases(
        point.config, ms, machine=point.machine, mesh=mesh_dict,
        meta=meta), False


# --------------------------------------------------------------------------
# Worker pool (one pool per device-count group)
# --------------------------------------------------------------------------

def _worker_init(n_devices: int) -> None:
    """Runs in the spawned worker before any job — and before the lazy jax
    import — so the forced host-platform device count can still take."""
    if n_devices > 1:
        # appended, not prepended: XLA flag parsing is last-occurrence-wins,
        # so this must beat any forced count inherited from the environment
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n_devices}")


def _point_job(point_dict: dict, iters: int, warmup: int,
               cache_dir: str | None, sweep_name: str | None,
               index: int = 0, attempt: int = 0,
               in_worker: bool = False) -> dict:
    """Worker entry: run one point, return a picklable outcome.

    ``index`` is the point's campaign ordinal and ``attempt`` its retry
    count — the fault-injection site identity (``crash_point:INDEX``,
    ``hang_point:INDEX:SECS``), passed explicitly because counters do not
    survive the process boundary.  ``in_worker=False`` (the inline path)
    skips crash/hang injection: an inline hang cannot be killed and an
    inline ``os._exit`` would take the campaign driver down with it.
    """
    point = SweepPoint.from_dict(point_dict)
    if in_worker:
        plan = faults.active_plan()
        plan.maybe_crash("crash_point", target=index, attempt=attempt)
        plan.maybe_hang("hang_point", target=index, attempt=attempt)
    try:
        rec, cached = run_point(point, iters=iters, warmup=warmup,
                                cache_dir=cache_dir, sweep_name=sweep_name)
    except Exception:
        return {"point": point_dict, "error": traceback.format_exc()}
    return {"point": point_dict, "record": json.loads(rec.to_json()),
            "cached": cached}


def _append_outcome(store, point: SweepPoint, outcome: dict) -> PointResult:
    from repro.trace.store import TraceRecord
    if outcome.get("error"):
        return PointResult(point, error=outcome["error"])
    rec = TraceRecord.from_dict(outcome["record"])
    store.append(rec)
    wall = sum(float(p.get("wall_s", 0.0)) for p in rec.phases.values())
    return PointResult(point, run_id=rec.run_id,
                       cached=bool(outcome.get("cached")), wall_s=wall)


def _resume_run_ids(store, journal: CampaignJournal | None,
                    sweep_name: str) -> dict[str, str]:
    """Point key → run_id for every point already completed in a prior
    invocation of this campaign.  Union of the journal's ``done`` events
    and a store scan (covers the crash window between the store append
    and the journal ``done`` line — the store is the source of truth)."""
    done: dict[str, str] = {}
    if journal is not None:
        done.update(journal.replay(sweep_name).done)
    try:
        for rec in store.records_where(
                lambda r: r.meta.get("sweep") == sweep_name):
            key = rec.meta.get("sweep_point")
            if key:
                done[str(key)] = rec.run_id
    except OSError:
        pass
    return done


def run_sweep(sweep: SweepSpec, *, store_path: str | None = None,
              workers: int | None = None,
              cache_dir: "str | None | type(Ellipsis)" = ...,
              progress: Callable[[str], None] | None = None,
              deadline_s: float | None = None,
              retries: int = 1,
              backoff_s: float = 0.25,
              resume: bool = False,
              journal_path: "str | None | type(Ellipsis)" = ...,
              ) -> SweepResult:
    """Run a whole campaign: expand, execute, persist one record per point.

    ``store_path=None`` resolves through the workspace rules
    (``$REPRO_WORKSPACE/sweep.jsonl``, else the legacy default); the
    ``cache_dir`` default resolves the same way (``None`` means *no*
    cache, so the sentinel is ``...``) — one root for both.

    ``workers``: pool size; ``0`` runs every point inline in this process
    (single-device points only — useful under pytest and for debugging).
    ``None`` picks ``min(4, cpu_count)`` for analytical sweeps but ``1``
    for measured ones: concurrent wall-clock samples contend for the same
    CPUs and skew each other, so parallel measurement is opt-in.

    Resilience knobs: ``deadline_s`` kills (and replaces) a worker whose
    point runs longer — mind that a worker's *first* point pays the jax
    import, so deadlines under ~30 s are asking for false kills;
    ``retries`` bounds extra attempts per point (backoff doubles from
    ``backoff_s`` each round) before the point is **quarantined**;
    ``resume=True`` skips points whose record already landed (journal ∪
    store scan, keyed by the point content hash — zero duplicates);
    ``journal_path`` defaults to ``sweep_journal.jsonl`` beside the store
    (``None`` disables journalling, and with it ``--resume``'s journal
    half).
    """
    from repro.trace.store import TraceStore

    store_path = resolve_sweep_store(store_path)
    if cache_dir is ...:
        cache_dir = resolve_sweep_cache(None)
    if journal_path is ...:
        journal_path = journal_path_for(store_path)
    journal = CampaignJournal(journal_path) if journal_path else None
    say = progress or (lambda s: None)
    points, skipped = sweep.expand()
    for p, reason in skipped:
        say(f"[skip] {p.label}: {reason}")
    store = TraceStore(store_path)
    results: list[PointResult] = []

    if workers is None:
        workers = 1 if sweep.measure else min(4, os.cpu_count() or 1)

    done_ids = (_resume_run_ids(store, journal, sweep.name)
                if resume else {})
    todo: list[tuple[int, SweepPoint]] = []
    for i, point in enumerate(points):
        run_id = done_ids.get(point.key)
        if run_id is not None:
            res = PointResult(point, run_id=run_id or None, resumed=True,
                              attempts=0)
            results.append(res)
            say(_ok_line(res))
        else:
            todo.append((i, point))

    attempts: dict[str, int] = {p.key: 0 for _, p in todo}
    errors: dict[str, str] = {}

    def record_attempt(point: SweepPoint) -> int:
        a = attempts[point.key]
        attempts[point.key] = a + 1
        if journal is not None:
            journal.log("attempt", sweep=sweep.name, point=point.key,
                        label=point.label, attempt=a)
        return a

    def settle(point: SweepPoint, outcome: dict) -> PointResult | None:
        """Store + journal one attempt's outcome.  Returns the final
        PointResult, or None if the point should be retried."""
        n = attempts[point.key]
        if not outcome.get("error"):
            res = _append_outcome(store, point, outcome)
            res.attempts = n
            if journal is not None:
                journal.log("done", sweep=sweep.name, point=point.key,
                            label=point.label, attempt=n - 1,
                            run_id=res.run_id)
            return res
        err = outcome["error"]
        errors[point.key] = err
        reason = err.strip().splitlines()[-1] if err.strip() else "unknown"
        if journal is not None:
            journal.log("fail", sweep=sweep.name, point=point.key,
                        label=point.label, attempt=n - 1, reason=reason)
        if n <= retries:
            return None                               # retry next round
        if journal is not None:
            journal.log("quarantine", sweep=sweep.name, point=point.key,
                        label=point.label, attempt=n - 1, reason=reason)
        return PointResult(point, error=err, attempts=n, quarantined=True)

    opts = (sweep.iters, sweep.warmup, cache_dir, sweep.name)

    if workers == 0:
        for i, point in todo:
            while True:
                a = record_attempt(point)
                outcome = _point_job(point.to_dict(), *opts,
                                     index=i, attempt=a, in_worker=False)
                res = settle(point, outcome)
                if res is not None:
                    break
                time.sleep(backoff_s * (2 ** a))
            results.append(res)
            say(_ok_line(res) if res.ok else f"[FAIL] {point.label}")
    else:
        by_dev: dict[int, list[tuple[int, SweepPoint]]] = {}
        for i, point in todo:
            by_dev.setdefault(point.n_devices, []).append((i, point))
        for n_devices, group in sorted(by_dev.items()):
            n_workers = min(workers, len(group))
            label_of = {p.key: p.label for _, p in group}
            with SupervisedPool(_point_job, n_workers,
                                init=_worker_init, initargs=(n_devices,),
                                deadline_s=deadline_s) as pool:
                pending = list(group)
                rnd = 0
                while pending:
                    tasks = []
                    for i, point in pending:
                        a = record_attempt(point)
                        tasks.append((point.key,
                                      (point.to_dict(), *opts, i, a, True)))
                    outcomes = pool.run(
                        tasks,
                        on_event=lambda kind, key: say(
                            f"[watchdog] {label_of[key]}: {kind}"))
                    retry = []
                    for i, point in pending:
                        out = outcomes[point.key]
                        if out.kind == "ok" and out.error is None:
                            outcome = out.value or {"error": "empty worker "
                                                             "reply"}
                        else:
                            outcome = {"error": out.error or out.kind}
                        res = settle(point, outcome)
                        if res is None:
                            retry.append((i, point))
                            continue
                        results.append(res)
                        say(_ok_line(res) if res.ok
                            else f"[FAIL] {point.label}")
                    if retry:
                        time.sleep(backoff_s * (2 ** min(rnd, 6)))
                    pending = retry
                    rnd += 1
    # keep campaign order (configs outermost), not completion order
    order = {p.key: i for i, p in enumerate(points)}
    results.sort(key=lambda r: order[r.point.key])
    return SweepResult(results, skipped)


def _ok_line(res: PointResult) -> str:
    if res.resumed:
        return f"[ok] {res.point.label} -> run {res.run_id} (resumed)"
    tag = " (cached)" if res.cached else ""
    wall = (f" wall {res.wall_s*1e3:.3f} ms" if res.wall_s else " bound-only")
    return f"[ok] {res.point.label} -> run {res.run_id}{wall}{tag}"
