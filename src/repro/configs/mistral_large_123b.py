"""mistral-large-123b [dense] [hf:mistralai/Mistral-Large-Instruct-2407]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b", family="dense",
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=32768, act="swiglu", rope_theta=1_000_000.0,
    tie_embeddings=False,
    source="hf:mistralai/Mistral-Large-Instruct-2407 (unverified)",
)

SMOKE = ModelConfig(
    name="mistral-large-123b-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=320, vocab_size=512, act="swiglu", tie_embeddings=False,
)
