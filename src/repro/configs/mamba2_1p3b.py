"""mamba2-1.3b [ssm] — SSD, attention-free [arXiv:2405.21060]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_n_groups=1,
    ssm_chunk=256, tie_embeddings=True,
    source="arXiv:2405.21060; hf:state-spaces/mamba2-1.3b (unverified)",
)

SMOKE = ModelConfig(
    name="mamba2-1.3b-smoke", family="ssm",
    n_layers=2, d_model=64, d_ff=0, vocab_size=512,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_n_groups=1,
    ssm_chunk=32,
)
