"""Config registry: ``--arch <id>`` → (full config, smoke config).

Cell applicability (task spec): ``long_500k`` only for sub-quadratic
families; encoder-only archs would skip decode (none assigned); deepcam is
the paper's own benchmark and uses image shapes, not the LM shape grid.
"""

from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ModelConfig, ShapeSpec

_MODULES = {
    "minitron-4b": "minitron_4b",
    "mistral-large-123b": "mistral_large_123b",
    "granite-8b": "granite_8b",
    "glm4-9b": "glm4_9b",
    "zamba2-1.2b": "zamba2_1p2b",
    "phi-3-vision-4.2b": "phi3_vision_4p2b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "mamba2-1.3b": "mamba2_1p3b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "deepcam": "deepcam",
}

ARCHS = tuple(k for k in _MODULES if k != "deepcam")   # the 10 assigned
ALL = tuple(_MODULES)


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _module(name).SMOKE


def cells(arch: str) -> list[ShapeSpec]:
    """The applicable (arch x shape) cells for the 40-cell grid."""
    cfg = get_config(arch)
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.supports_long_context:
            continue   # quadratic-attention archs skip 500k decode (DESIGN §5)
        out.append(s)
    return out


def all_cells() -> list[tuple[str, ShapeSpec]]:
    return [(a, s) for a in ARCHS for s in cells(a)]
