"""Config registry: ``--arch <id>`` → (full config, smoke config).

Cell applicability (task spec): ``long_500k`` only for sub-quadratic
families; encoder-only archs would skip decode (none assigned); deepcam is
the paper's own benchmark and uses image shapes, not the LM shape grid.
"""

from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ModelConfig, ShapeSpec

_MODULES = {
    "minitron-4b": "minitron_4b",
    "mistral-large-123b": "mistral_large_123b",
    "granite-8b": "granite_8b",
    "glm4-9b": "glm4_9b",
    "zamba2-1.2b": "zamba2_1p2b",
    "phi-3-vision-4.2b": "phi3_vision_4p2b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "mamba2-1.3b": "mamba2_1p3b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "deepcam": "deepcam",
}

ARCHS = tuple(k for k in _MODULES if k != "deepcam")   # the 10 assigned
ALL = tuple(_MODULES)


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _module(name).SMOKE


def family_of(name: str) -> str:
    return get_config(name).family


def select(selector: str) -> tuple[str, ...]:
    """Expand one config selector (``repro.sweep`` spec entries):

    * ``"all"``            → the assigned archs (``ARCHS``),
    * ``"family:<fam>"``   → every assigned arch of that family,
    * an exact name        → itself (including ``deepcam``).
    """
    if selector == "all":
        return ARCHS
    if selector.startswith("family:"):
        fam = selector.removeprefix("family:")
        out = tuple(a for a in ARCHS if family_of(a) == fam)
        if not out:
            raise KeyError(f"no assigned arch has family {fam!r}")
        return out
    if selector not in _MODULES:
        raise KeyError(f"unknown arch {selector!r}; known: {sorted(_MODULES)} "
                       "(or 'all' / 'family:<fam>')")
    return (selector,)


def select_many(selectors) -> tuple[str, ...]:
    """Expand + dedupe a list of selectors, preserving first-seen order."""
    seen: dict[str, None] = {}
    for sel in selectors:
        for name in select(sel):
            seen.setdefault(name)
    return tuple(seen)


def cells(arch: str) -> list[ShapeSpec]:
    """The applicable (arch x shape) cells for the 40-cell grid."""
    cfg = get_config(arch)
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.supports_long_context:
            continue   # quadratic archs skip 500k decode (docs/DESIGN.md §5)
        out.append(s)
    return out


def all_cells() -> list[tuple[str, ShapeSpec]]:
    return [(a, s) for a in ARCHS for s in cells(a)]
