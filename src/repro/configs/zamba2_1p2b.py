"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attn [arXiv:2411.15242].

38 Mamba-2 layers; ONE shared attention+MLP block inserted every
``hybrid_group`` layers (per-site input norms de-share it).  hybrid_group=6
is a documented assumption (the paper alternates two shared blocks; we use
the single-shared-block variant of zamba2-1.2b).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000, act="gelu",
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_n_groups=1,
    hybrid_group=6,
    source="arXiv:2411.15242; hf:Zyphra/Zamba2-1.2B",
)

SMOKE = ModelConfig(
    name="zamba2-1.2b-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, act="gelu",
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_n_groups=1,
    hybrid_group=2,
)
