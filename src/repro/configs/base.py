"""Model / run configuration system.

Every assigned architecture is a :class:`ModelConfig` (exact public numbers)
plus a reduced ``smoke`` variant of the same family for CPU tests.  Run-time
behaviour (precision policy, remat, parallelism) lives in :class:`RunConfig`
so the same model can be lowered under different distribution strategies —
that separation is what the §Perf hillclimbs iterate on.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm", "audio",
                 "cnn"]

#: valid values of :attr:`RunConfig.fusion` — the one list every CLI
#: ``--fusion`` choice and sweep-axis validation imports.  ``off`` =
#: reference lowerings; ``static`` = the PR 4 behaviour (eligibility
#: predicates alone route to the fused kernels); ``auto`` = measured-best
#: per call site through the dispatch table (``repro.tune.dispatch``);
#: ``measured`` = explicit alias of ``auto``.
FUSION_MODES = ("off", "static", "auto", "measured")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    act: str = "swiglu"              # swiglu | geglu | gelu | relu2
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = True
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    moe_shared_ff: int = 0           # shared-expert ffn width (kimi-style)
    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_n_groups: int = 1
    ssm_conv_width: int = 4
    ssm_chunk: int = 128             # SSD chunk length
    # --- hybrid (zamba2): one *shared* attn+mlp block every k ssm layers ---
    hybrid_group: int = 0            # 0 = not hybrid
    # --- enc-dec ---
    n_encoder_layers: int = 0
    # --- multimodal stubs ---
    n_prefix_embeds: int = 0         # VLM patch / audio frame embeddings
    # --- provenance ---
    source: str = ""

    def __post_init__(self):
        if self.n_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_heads and not self.n_kv_heads:
            object.__setattr__(self, "n_kv_heads", self.n_heads)

    # -- derived -----------------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        """Embedding-table vocab padded to a TP-friendly multiple of 128
        (Megatron-style): keeps the vocab axis shardable on any mesh whose
        model axis divides 128.  Padded logit columns are masked in the loss.
        """
        return (self.vocab_size + 127) // 128 * 128

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing → long_500k cell applies."""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND MODEL_FLOPS and memory tables)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        total = V * D  # embedding
        if not self.tie_embeddings:
            total += V * D

        def attn_params() -> int:
            q = D * self.n_heads * self.head_dim
            kv = 2 * D * self.n_kv_heads * self.head_dim
            o = self.n_heads * self.head_dim * D
            return q + kv + o

        def mlp_params(ff: int) -> int:
            mult = 3 if self.act in ("swiglu", "geglu") else 2
            return mult * D * ff

        def ssm_params() -> int:
            di, G, N = self.d_inner, self.ssm_n_groups, self.ssm_state
            H = self.ssm_heads
            in_p = D * (2 * di + 2 * G * N + H)
            conv = self.ssm_conv_width * (di + 2 * G * N)
            out_p = di * D
            return in_p + conv + out_p + 2 * H + di  # A_log, D, norm

        if self.family in ("dense", "vlm"):
            total += L * (attn_params() + mlp_params(F) + 2 * D)
        elif self.family == "moe":
            per_expert = mlp_params(F)
            total += L * (attn_params() + self.n_experts * per_expert
                          + D * self.n_experts            # router
                          + mlp_params(self.moe_shared_ff)
                          + 2 * D)
        elif self.family == "ssm":
            total += L * (ssm_params() + D)
        elif self.family == "hybrid":
            n_groups = max(1, L // self.hybrid_group) if self.hybrid_group else 1
            total += L * (ssm_params() + D)
            total += attn_params() + mlp_params(F) + 2 * D  # one SHARED block
            del n_groups
        elif self.family in ("encdec", "audio"):
            enc = self.n_encoder_layers * (attn_params() + mlp_params(F) + 2 * D)
            dec = L * (2 * attn_params() + mlp_params(F) + 3 * D)
            total += enc + dec
        total += D  # final norm
        return total

    def active_param_count(self) -> int:
        """Per-token active params (MoE: top-k experts only) for 6·N_active·D."""
        if self.family != "moe":
            return self.param_count()
        D, F, L = self.d_model, self.d_ff, self.n_layers
        mult = 3 if self.act in ("swiglu", "geglu") else 2
        dense_like = self.param_count() - L * self.n_experts * mult * D * F
        return dense_like + L * self.experts_per_token * mult * D * F


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Execution policy — the knobs the §Perf hillclimbs turn."""

    # precision (paper §IV-C AMP study): O0=fp32, O1=bf16 compute/fp32 params,
    # O2=bf16 everywhere (incl. optimizer 2nd moment)
    amp: str = "O1"
    # remat: "none" | "dots" | "full"
    remat: str = "none"
    # parallelism
    tp: bool = True                  # Megatron TP over "model"
    fsdp: bool = False               # ZeRO-3 param shard over "data"
    sp: bool = False                 # sequence-sharded activations
    ep: bool = True                  # experts over "model"
    # attention lowering: "einsum" | "chunked" | "flash" (Pallas)
    attn_impl: str = "einsum"
    attn_chunk: int = 1024
    # SSD lowering: "xla" (chunked dual form in jnp) | "kernel" (Pallas)
    ssd_impl: str = "xla"
    # attention softmax statistics in fp32 (paper §IV-C O1 semantics);
    # False = bf16 stats (the O2-style aggressive extension — halves the
    # live score tensors; take care with very long contexts)
    softmax_f32: bool = True
    # logits: compute vocab-sharded cross-entropy without full gather
    sharded_logits: bool = True
    # gradient accumulation microbatches
    microbatches: int = 1
    # cross-pod gradient compression (int8 + error feedback)
    grad_compression: bool = False
    # optimizer: "adamw" | "adafactor"
    optimizer: str = "adamw"
    # deepcam lowering variant (paper's TF-vs-PyTorch comparison)
    impl: str = "reference"
    # fused-kernel routing (repro.kernels.fused, docs/DESIGN.md §12/§16):
    # "off" = reference lowerings everywhere; "static" = route the
    # census's memory-bound hot chains (norm+residual+cast, swiglu
    # epilogue, AdamW leaf update, embedding backward) through the fused
    # Pallas kernels whenever the eligibility predicates allow; "auto"
    # (alias "measured") = measured-best per call site — eligibility
    # stays a hard correctness gate, and the fused-vs-reference choice
    # comes from the dispatch table (repro.tune.dispatch)
    fusion: str = "off"
    # MoE combine lowering: "default" (XLA masked-gather → model-axis
    # all-reduce), "reshard" (explicitly bring the expert buffer back to
    # batch sharding in bf16, gather locally), "a2a" (shard the sorted-token
    # dim over model so dispatch/combine move only expert-local slices)
    moe_combine: str = "default"

    def __post_init__(self):
        # an unknown fusion string used to silently mean "off" (the ops
        # predicate only checked == "auto"); fail loudly instead
        if self.fusion not in FUSION_MODES:
            raise ValueError(
                f"unknown fusion mode {self.fusion!r}; valid: "
                f"{', '.join(FUSION_MODES)}")

    @property
    def param_dtype(self):
        import jax.numpy as jnp
        return jnp.float32 if self.amp in ("O0", "O1") else jnp.bfloat16

    @property
    def compute_dtype(self):
        import jax.numpy as jnp
        return jnp.float32 if self.amp == "O0" else jnp.bfloat16


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell (assigned per task spec)."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}
