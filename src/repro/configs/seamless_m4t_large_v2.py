"""seamless-m4t-large-v2 [audio] — enc-dec, multimodal [arXiv:2308.11596].

Transformer backbone only (per task spec): 24-layer encoder over precomputed
speech-frame embeddings (the w2v-BERT frontend is a stub) + 24-layer decoder
with cross-attention.  Frame rate assumption (documented): encoder length =
seq_len // 8 (conformer 8x downsampling of 16 kHz fbank frames).
"""

from repro.configs.base import ModelConfig

FRAME_DOWNSAMPLE = 8

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, n_encoder_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=8192, vocab_size=256_206, act="gelu", tie_embeddings=False,
    source="arXiv:2308.11596; hf:facebook/seamless-m4t-v2-large",
)

SMOKE = ModelConfig(
    name="seamless-m4t-large-v2-smoke", family="audio",
    n_layers=2, n_encoder_layers=2,
    d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, act="gelu", tie_embeddings=False,
)
