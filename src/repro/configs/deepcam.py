"""DeepCAM — the paper's own case-study network (§III-B).

Not an LM: a DeepLabv3+-style segmentation CNN over (B, 768, 1152, 16)
climate images (the paper's input resolution), reproduced in two lowerings
(``reference`` / ``fused``, see ``repro.models.deepcam``).  The ``d_model``
field carries the ResNet stem width.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepcam", family="cnn",
    n_layers=50, d_model=64, d_ff=0, vocab_size=0,
    source="paper refs [21],[34],[36]; MLPerf-HPC deepcam",
)

SMOKE = ModelConfig(
    name="deepcam-smoke", family="cnn",
    n_layers=50, d_model=8, d_ff=0, vocab_size=0,
)

# paper input resolution (CAM5 climate snapshots)
IMAGE_HW = (768, 1152)
SMOKE_HW = (64, 96)
