"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP stub frontend.

[hf:microsoft/Phi-3-vision-128k-instruct].  The CLIP-L/14 image encoder is a
STUB per task spec: ``input_specs`` provides 576 precomputed patch embeddings
(336px / 14px patches, single crop) prepended to the token sequence.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, vocab_size=32064, act="swiglu", rope_theta=10_000.0,
    n_prefix_embeds=576,
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)

SMOKE = ModelConfig(
    name="phi-3-vision-4.2b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, act="swiglu", n_prefix_embeds=16,
)
