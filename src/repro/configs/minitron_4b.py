"""minitron-4b [dense] — pruned nemotron [arXiv:2407.14679; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=9216, vocab_size=256_000, act="relu2",   # nemotron uses squared-relu
    source="arXiv:2407.14679; hf:nvidia/Minitron-4B-Base",
)

SMOKE = ModelConfig(
    name="minitron-4b-smoke", family="dense",
    n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, head_dim=16,
    d_ff=288, vocab_size=512, act="relu2",
)
