"""granite-8b [dense] — llama-arch, code [arXiv:2405.04324; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=49152, act="swiglu",
    source="arXiv:2405.04324; hf:ibm-granite/granite-8b-code-base",
)

SMOKE = ModelConfig(
    name="granite-8b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=224, vocab_size=512, act="swiglu",
)
