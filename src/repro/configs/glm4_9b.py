"""glm4-9b [dense] — RoPE, extreme GQA (kv=2) [hf:THUDM/glm-4-9b]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, head_dim=128,
    d_ff=13696, vocab_size=151_552, act="swiglu", tie_embeddings=False,
    source="hf:THUDM/glm-4-9b",
)

SMOKE = ModelConfig(
    name="glm4-9b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=224, vocab_size=512, act="swiglu", tie_embeddings=False,
)
