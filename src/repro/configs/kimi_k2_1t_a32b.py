"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8
[arXiv:2501.kimi2; unverified].

Per the paper-table numbers: 61 layers, d_model 7168, 64 query heads
(GQA kv=8), per-expert FFN width 2048, 384 routed experts top-8 + one
shared expert (moe_shared_ff=2048).  head_dim = 7168/64 = 112 (derived).

1T params cannot fit AdamW-fp32 training state on 256/512 v5e chips; the
training RunConfig defaults to Adafactor for this arch (see EXPERIMENTS.md
§Dry-run memory notes) — the dry-run still compiles and reports honest
memory_analysis either way.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
    d_ff=2048, vocab_size=163_840, act="swiglu", tie_embeddings=False,
    n_experts=384, experts_per_token=8, moe_shared_ff=2048,
    source="arXiv:2501.kimi2 (unverified paper-table)",
)

SMOKE = ModelConfig(
    name="kimi-k2-1t-a32b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=32, vocab_size=512, act="swiglu", tie_embeddings=False,
    n_experts=8, experts_per_token=2, moe_shared_ff=32,
)
