"""repro.obs — fleet observability over the workspace stores.

Three capabilities, all store-only (nothing re-lowers or re-times):

* :func:`merge_workspace` — machine-keyed union of a remote workspace's
  trace/sweep/tune stores (+ bench harvests) into the local one, with
  skip-and-report conflict handling and provenance in ``workspace.json``;
* :func:`collect_series` / :func:`gate_series` — perf-trend series over
  stored trace records and harvested ``BENCH_*.json`` files, with an
  ASCII sparkline report and a CI regression gate;
* :func:`advise` — a rule engine mining stored trace payloads for known
  bottleneck patterns (launch overhead, scatter-heavy backward, tune
  mismatches, bandwidth-pinned levels), emitting ranked, evidence-cited
  remediations — the DeepProf direction pointed at our own stores.

``python -m repro {merge,trend,advise}`` (``repro.cli``) and
``Session.merge/trend/advise`` are this package as a CLI/API.

Lazy (PEP 562) like ``repro.session``: importing ``repro.obs`` pulls in
no jax and no store classes.
"""

from typing import Any

_LAZY = {
    "Finding": "repro.obs.advisor",
    "RULES": "repro.obs.advisor",
    "advise": "repro.obs.advisor",
    "render_findings": "repro.obs.advisor",
    "MergeReport": "repro.obs.merge",
    "merge_workspace": "repro.obs.merge",
    "render_merge": "repro.obs.merge",
    "Regression": "repro.obs.trend",
    "TrendSeries": "repro.obs.trend",
    "collect_series": "repro.obs.trend",
    "gate_series": "repro.obs.trend",
    "render_trend": "repro.obs.trend",
    "sparkline": "repro.obs.trend",
}

__all__ = sorted(_LAZY)


def __getattr__(name: str) -> Any:
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(mod), name)
