"""Perf-trend tracking: time series over the workspace's stored history.

Two sources, one series shape:

* **Trace records** (``trace.jsonl`` + ``sweep.jsonl``): per
  ``(config, machine, host, fusion)`` key, series of step wall time,
  achieved GFLOP/s, %-of-roofline, and per-memory-level bound fractions
  (``hbm``/``vmem`` streaming time over measured wall — the hierarchical
  view collapsed to one number per level);
* **Bench harvests** (``bench/BENCH_*.json`` written by
  ``benchmarks.run``): per-suite wall seconds and per-row
  ``us_per_call``, keyed by the host fingerprint each file now stamps.

A series is plotted as an ASCII sparkline (oldest → newest) and gated:
``gate_series`` flags any lower-is-better series whose newest point
exceeds the median of its recent history by more than the tolerance —
the CI perf gate the ``BENCH_*.json`` harvester was built for.  Exit
codes belong to the CLI (``python -m repro trend --gate``).

Import-light: stores, machine models and the aggregate helpers load
inside the functions (the workspace import rule).
"""

from __future__ import annotations

import dataclasses
import glob
import json
import os
import statistics
from typing import Any, Iterable

#: sparkline glyphs, low → high
_SPARK = "▁▂▃▄▅▆▇█"

#: how many trailing points (excluding the newest) form the gate baseline
BASELINE_WINDOW = 5

#: default relative tolerance for the regression gate
DEFAULT_TOLERANCE = 0.25


@dataclasses.dataclass(frozen=True)
class TrendPoint:
    timestamp: float
    value: float
    ref: str                      # run_id / harvest file — the evidence


@dataclasses.dataclass
class TrendSeries:
    """One metric's history under one fleet key, oldest first."""

    key: str                      # e.g. "minitron-4b|cpu-host|hostA|off"
    source: str                   # "trace" | "bench"
    metric: str                   # "wall_s" | "gflops" | "us_per_call" | ...
    lower_is_better: bool
    points: list[TrendPoint] = dataclasses.field(default_factory=list)

    @property
    def values(self) -> list[float]:
        return [p.value for p in self.points]

    @property
    def newest(self) -> TrendPoint:
        return self.points[-1]

    def baseline(self) -> float | None:
        """Median of the recent history *before* the newest point."""
        prior = self.values[:-1][-BASELINE_WINDOW:]
        return statistics.median(prior) if prior else None


def sparkline(values: Iterable[float]) -> str:
    vals = list(values)
    if not vals:
        return ""
    lo, hi = min(vals), max(vals)
    if hi <= lo:
        return _SPARK[3] * len(vals)
    span = hi - lo
    return "".join(
        _SPARK[min(len(_SPARK) - 1,
                   int((v - lo) / span * (len(_SPARK) - 1) + 0.5))]
        for v in vals)


# --------------------------------------------------------------------------
# trace-store series
# --------------------------------------------------------------------------

def _trace_key(rec: Any) -> str:
    host = rec.host.get("host", "?") if isinstance(rec.host, dict) else "?"
    fusion = str(rec.meta.get("fusion", "off"))
    return f"{rec.config}|{rec.machine}|{host}|{fusion}"


def trace_series(records: Iterable[Any]) -> list[TrendSeries]:
    """Series from trace/sweep records: wall, achieved GFLOP/s,
    %-of-roofline, per-level bound fractions per fleet key.

    Only *measured* records (wall > 0) contribute — analytical sweep
    payloads have no time axis to trend.
    """
    from repro.sweep.aggregate import summary_row

    metrics = (("wall_s", True), ("gflops", False),
               ("pct_of_roofline", False), ("hbm_frac", False),
               ("vmem_frac", False))
    by_key: dict[tuple[str, str], TrendSeries] = {}
    for rec in sorted(records, key=lambda r: r.timestamp):
        row = summary_row(rec)
        if not row["measured"]:
            continue
        key = _trace_key(rec)
        vals = {"wall_s": row["wall_s"],
                "gflops": row["achieved_flops_per_s"] / 1e9,
                "pct_of_roofline": row["pct_of_roofline"],
                "hbm_frac": row["hbm_frac"],
                "vmem_frac": row["vmem_frac"]}
        for metric, lower in metrics:
            s = by_key.setdefault((key, metric), TrendSeries(
                key=key, source="trace", metric=metric,
                lower_is_better=lower))
            s.points.append(TrendPoint(rec.timestamp, vals[metric],
                                       ref=f"run {rec.run_id}"))
    return list(by_key.values())


# --------------------------------------------------------------------------
# BENCH_*.json series
# --------------------------------------------------------------------------

def bench_files(dirs: Iterable[str]) -> list[str]:
    out: list[str] = []
    for d in dirs:
        if d and os.path.isdir(d):
            out.extend(glob.glob(os.path.join(d, "BENCH_*.json")))
    # the UTC-stamped file name sorts chronologically; dedupe merged copies
    seen: dict[str, str] = {}
    for p in sorted(out, key=os.path.basename):
        seen.setdefault(os.path.basename(p), p)
    return list(seen.values())


def load_bench(path: str) -> dict[str, Any] | None:
    """One harvest file, or ``None`` when unreadable (never fatal)."""
    try:
        with open(path) as f:
            doc = json.load(f)
        return doc if isinstance(doc, dict) and "suites" in doc else None
    except (OSError, ValueError):
        return None


def bench_series(dirs: Iterable[str]) -> list[TrendSeries]:
    """Per-suite wall and per-row ``us_per_call`` series across harvest
    files, keyed by the stamped host fingerprint (``unknown`` for files
    written before the stamp existed)."""
    by_key: dict[tuple[str, str], TrendSeries] = {}
    for path in bench_files(dirs):
        doc = load_bench(path)
        if doc is None:
            continue
        ts = float(doc.get("timestamp", 0.0))
        host = doc.get("host", {}).get("host", "unknown") \
            if isinstance(doc.get("host"), dict) else "unknown"
        ref = os.path.basename(path)
        for suite, s in doc.get("suites", {}).items():
            if not isinstance(s, dict) or not s.get("ok", False):
                continue
            key = f"{suite}|{host}"
            series = by_key.setdefault((key, "wall_s"), TrendSeries(
                key=key, source="bench", metric="wall_s",
                lower_is_better=True))
            series.points.append(TrendPoint(ts, float(s.get("wall_s", 0.0)),
                                            ref=ref))
            for row in s.get("rows", ()):
                us = float(row.get("us_per_call", 0.0))
                if us <= 0:
                    continue                 # derived-only rows: no timing
                rkey = f"{suite}/{row.get('name', '?')}|{host}"
                rs = by_key.setdefault((rkey, "us_per_call"), TrendSeries(
                    key=rkey, source="bench", metric="us_per_call",
                    lower_is_better=True))
                rs.points.append(TrendPoint(ts, us, ref=ref))
    return list(by_key.values())


# --------------------------------------------------------------------------
# collection, gate, rendering
# --------------------------------------------------------------------------

def default_bench_dirs(workspace: Any) -> list[str]:
    """Harvest locations: the workspace ``bench/`` dir, falling back to
    the legacy ``benchmarks/results`` + repo-root copies when the
    workspace has none (pre-workspace history stays visible)."""
    dirs = [workspace.bench_dir]
    if not glob.glob(os.path.join(workspace.bench_dir, "BENCH_*.json")):
        from repro.session.workspace import LEGACY_BENCH_DIR
        repo_root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
        dirs += [LEGACY_BENCH_DIR, repo_root]
    return dirs


def collect_series(workspace: Any, config: str | None = None,
                   bench_dirs: Iterable[str] | None = None
                   ) -> list[TrendSeries]:
    """Every trend series the workspace can produce, trace + bench."""
    recs = list(workspace.trace_store.records(config))
    sweep_recs = workspace.sweep_store.records(config)
    out = trace_series(recs + sweep_recs)
    if config is None:
        out += bench_series(bench_dirs if bench_dirs is not None
                            else default_bench_dirs(workspace))
    out.sort(key=lambda s: (s.source, s.key, s.metric))
    return out


@dataclasses.dataclass(frozen=True)
class Regression:
    series: TrendSeries
    baseline: float
    rel: float                    # newest/baseline - 1 (positive = slower)
    baseline_ref: str = ""        # pinned anchor (empty = rolling median)

    def describe(self) -> str:
        s = self.series
        anchor = (f"pinned {self.baseline_ref}" if self.baseline_ref
                  else "baseline")
        return (f"{s.key} [{s.metric}]: {s.newest.value:.6g} vs {anchor} "
                f"{self.baseline:.6g} (+{100 * self.rel:.1f}%, "
                f"{s.newest.ref})")


def pinned_baseline(series: TrendSeries, run_id: str) -> TrendPoint | None:
    """The series point written by ``run_id`` (prefix match, same rule
    as ``TraceStore.run``) — ``None`` when this series never saw it."""
    want = f"run {run_id}"
    for p in series.points:
        if p.ref == want or p.ref.startswith(want):
            return p
    return None


def gate_series(series: Iterable[TrendSeries],
                tolerance: float = DEFAULT_TOLERANCE,
                baseline_run: str | None = None) -> list[Regression]:
    """Lower-is-better series whose newest point regressed past the
    tolerance vs its baseline.

    The default baseline is the median of the recent history (rolling,
    :data:`BASELINE_WINDOW`).  ``baseline_run`` pins it instead to the
    value a tagged known-good run wrote (``repro trend tag`` +
    ``--baseline``): drift can no longer creep in through a slowly
    degrading median, and series that never saw the pinned run (bench
    harvests, configs added later) are skipped rather than mis-gated.
    """
    flags: list[Regression] = []
    for s in series:
        if not s.lower_is_better or len(s.points) < 2:
            continue
        ref = ""
        if baseline_run is not None:
            pin = pinned_baseline(s, baseline_run)
            if pin is None or pin is s.newest:
                continue
            base, ref = pin.value, pin.ref
        else:
            base = s.baseline()
        if base is None or base <= 0:
            continue
        rel = s.newest.value / base - 1.0
        if rel > tolerance:
            flags.append(Regression(series=s, baseline=base, rel=rel,
                                    baseline_ref=ref))
    flags.sort(key=lambda r: -r.rel)
    return flags


def _fmt_value(s: TrendSeries) -> str:
    v = s.newest.value
    if s.metric == "wall_s":
        return f"{v * 1e3:.3f}ms"
    if s.metric == "us_per_call":
        return f"{v:.1f}us"
    if s.metric in ("pct_of_roofline", "hbm_frac", "vmem_frac"):
        return f"{100 * v:.1f}%"
    return f"{v:.3g}"


def render_trend(series: list[TrendSeries],
                 regressions: list[Regression] | None = None,
                 max_rows: int = 40) -> str:
    """The trend report: one sparkline row per series, regressions
    (when gated) called out at the bottom."""
    if not series:
        return ("trend: no history yet — run `python -m repro record` / "
                "`python -m benchmarks.run` into this workspace first")
    flagged = {id(r.series) for r in (regressions or [])}
    lines = [f"{'series':<52}{'metric':<16}{'n':>3}  "
             f"{'newest':>10}  trend"]
    shown = 0
    for s in series:
        if shown >= max_rows:
            lines.append(f"... {len(series) - shown} more series "
                         "(raise --max-rows)")
            break
        mark = "!" if id(s) in flagged else " "
        lines.append(f"{s.key[:51]:<52}{s.metric:<16}{len(s.points):>3}  "
                     f"{_fmt_value(s):>10}  {sparkline(s.values)}{mark}")
        shown += 1
    if regressions is None:
        return "\n".join(lines)
    if regressions:
        lines.append("")
        lines.append(f"gate: {len(regressions)} regression(s) past "
                     "tolerance:")
        lines += [f"  ! {r.describe()}" for r in regressions]
    else:
        gated = sum(1 for s in series
                    if s.lower_is_better and len(s.points) >= 2)
        lines.append("")
        lines.append(f"gate: OK ({gated} series with history, "
                     "0 regressions)")
    return "\n".join(lines)
