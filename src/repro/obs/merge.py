"""Fleet workspace merge: union a remote workspace's stores into local.

A production fleet runs the same characterization on many hosts, each
writing its own workspace (PR 5).  ``merge_workspace`` folds a remote
root's ``trace.jsonl`` / ``sweep.jsonl`` / ``tune.json`` (plus harvested
``bench/BENCH_*.json``) into the local workspace so one root can hold
the whole fleet's history — the report/trend/advise side then groups by
the machine + host keys every record already carries.

Merge identity per store:

* trace / sweep (JSONL): ``run_id`` — every record stamped one at write
  time (uuid); records with no run_id fall back to a content hash.
* tune (JSON): the store key ``kernel|backend|shape|dtype|machine`` —
  the machine key means two hosts' winners coexist; a same-key conflict
  resolves to the newer ``timestamp`` (and is reported).  The store's
  ``dispatch`` namespace (site-keyed fused-vs-reference winners,
  docs/DESIGN.md §16) merges under the same rule.
* bench: the ``BENCH_<utc timestamp>.json`` file name.

The local store is never corrupted: remote corrupt lines, records from a
*newer* schema, and same-id-different-content conflicts are skipped and
counted in the returned :class:`MergeReport` (same never-fatal rule as
the stores themselves).  Merging is idempotent — a second merge of the
same remote adds nothing — and commutative up to conflict resolution.

This module imports no jax and no store classes at module scope (the
workspace import-light rule); stores load lazily inside the functions.
"""

from __future__ import annotations

import dataclasses
import glob
import hashlib
import json
import os
import shutil
import time
from typing import Any


@dataclasses.dataclass
class MergeReport:
    """What one store's merge did (counts + human-readable notes)."""

    store: str                    # "trace" | "sweep" | "tune" | "bench"
    n_added: int = 0
    n_dup: int = 0                # identical record already present
    n_conflict: int = 0           # same identity, different content
    n_skipped: int = 0            # corrupt / newer-schema remote records
    notes: list[str] = dataclasses.field(default_factory=list)

    @property
    def merged_any(self) -> bool:
        return self.n_added > 0

    def note(self, msg: str) -> None:
        self.notes.append(msg)

    def describe(self) -> str:
        head = (f"{self.store:<6} +{self.n_added} added, {self.n_dup} "
                f"duplicate(s), {self.n_conflict} conflict(s), "
                f"{self.n_skipped} skipped")
        return "\n".join([head] + [f"    {n}" for n in self.notes])


def _record_identity(d: dict[str, Any]) -> str:
    """run_id when stamped, else a stable content hash (hand-rolled or
    pre-run_id records still dedupe)."""
    rid = d.get("run_id")
    if rid:
        return str(rid)
    blob = json.dumps(d, sort_keys=True).encode()
    return "sha1:" + hashlib.sha1(blob).hexdigest()[:16]


def merge_jsonl(local_path: str, remote_path: str,
                store: str = "trace") -> MergeReport:
    """Union remote JSONL trace records into the local file by run_id.

    Only lines that parse, carry a known schema, and are not already
    present locally are appended; everything else is counted and noted.
    Appends raw remote lines verbatim (provenance bytes preserved).
    """
    from repro.trace.store import SCHEMA_VERSION

    rep = MergeReport(store=store)
    if not os.path.exists(remote_path):
        rep.note(f"remote has no {os.path.basename(remote_path)} — nothing "
                 "to merge")
        return rep

    local: dict[str, dict] = {}
    if os.path.exists(local_path):
        with open(local_path) as f:
            for line in f:
                if not line.strip():
                    continue
                try:
                    d = json.loads(line)
                except json.JSONDecodeError:
                    continue                 # local corruption isn't ours
                local[_record_identity(d)] = d

    additions: list[str] = []
    with open(remote_path) as f:
        for i, line in enumerate(f, 1):
            if not line.strip():
                continue
            try:
                d = json.loads(line)
            except json.JSONDecodeError:
                rep.n_skipped += 1
                rep.note(f"{remote_path}:{i}: corrupt line skipped")
                continue
            if not isinstance(d, dict):
                rep.n_skipped += 1
                rep.note(f"{remote_path}:{i}: non-record line skipped")
                continue
            if d.get("schema_version", 0) > SCHEMA_VERSION:
                rep.n_skipped += 1
                rep.note(f"{remote_path}:{i}: schema "
                         f"{d.get('schema_version')} > {SCHEMA_VERSION} "
                         "(newer writer) — skipped")
                continue
            ident = _record_identity(d)
            if ident in local:
                if local[ident] == d:
                    rep.n_dup += 1
                else:
                    rep.n_conflict += 1
                    rep.note(f"{remote_path}:{i}: run {ident} differs from "
                             "the local record — local kept")
                continue
            local[ident] = d
            additions.append(line.rstrip("\n"))

    if additions:
        os.makedirs(os.path.dirname(os.path.abspath(local_path)),
                    exist_ok=True)
        with open(local_path, "a") as f:
            for line in additions:
                f.write(line + "\n")
        rep.n_added = len(additions)
    return rep


def merge_tune(local_path: str, remote_path: str) -> MergeReport:
    """Union a remote tune store's winners into the local one by store
    key; same-key conflicts resolve to the newer ``timestamp``."""
    from repro.tune.store import SCHEMA_VERSION, TuneStore

    rep = MergeReport(store="tune")
    if not os.path.exists(remote_path):
        rep.note("remote has no tune.json — nothing to merge")
        return rep
    try:
        with open(remote_path) as f:
            doc = json.load(f)
        if not isinstance(doc, dict):
            raise ValueError("not a JSON object")
    except (OSError, ValueError) as e:
        rep.n_skipped += 1
        rep.note(f"{remote_path}: corrupt remote tune store skipped ({e})")
        return rep
    if doc.get("schema_version", 0) > SCHEMA_VERSION:
        rep.n_skipped += 1
        rep.note(f"{remote_path}: schema {doc.get('schema_version')} > "
                 f"{SCHEMA_VERSION} (newer writer) — skipped")
        return rep
    remote = doc.get("records")
    remote_dispatch = doc.get("dispatch")
    if not isinstance(remote, dict) and not isinstance(remote_dispatch,
                                                       dict):
        rep.note("remote tune store holds no records")
        return rep

    store = TuneStore(local_path)

    def _union(remote_ns: dict, local_ns: dict, what: str) -> dict:
        """Same-key union: identical = dup, different = newer timestamp
        wins (the one merge rule both namespaces share)."""
        additions: dict[str, dict] = {}
        for key, d in sorted(remote_ns.items()):
            if not isinstance(d, dict):
                rep.n_skipped += 1
                rep.note(f"{what} key {key!r}: non-record value skipped")
                continue
            if d.get("schema_version", 0) > SCHEMA_VERSION:
                rep.n_skipped += 1
                rep.note(f"{what} key {key!r}: newer-schema record "
                         "skipped")
                continue
            mine = local_ns.get(key)
            if mine is None:
                additions[key] = d
            elif mine == d:
                rep.n_dup += 1
            else:
                rep.n_conflict += 1
                if float(d.get("timestamp", 0)) > float(
                        mine.get("timestamp", 0)):
                    additions[key] = d
                    rep.note(f"{what} key {key!r}: remote winner is "
                             "newer — replaced local")
                else:
                    rep.note(f"{what} key {key!r}: local winner is "
                             "newer — kept")
        return additions

    if isinstance(remote, dict):
        additions = _union(remote, dict(store._load()), "tune")
        if additions:
            store.put_many(additions)
            rep.n_added += len(additions)
    if isinstance(remote_dispatch, dict):
        additions = _union(remote_dispatch, dict(store._load_dispatch()),
                           "dispatch")
        if additions:
            store.put_dispatch_many(additions)
            rep.n_added += len(additions)
    return rep


def merge_bench(local_dir: str, remote_dir: str) -> MergeReport:
    """Copy remote ``BENCH_*.json`` harvest files absent locally (the
    file name is the identity: one per run per host timestamp)."""
    rep = MergeReport(store="bench")
    if not os.path.isdir(remote_dir):
        rep.note("remote has no bench/ dir — nothing to merge")
        return rep
    for src in sorted(glob.glob(os.path.join(remote_dir, "BENCH_*.json"))):
        dst = os.path.join(local_dir, os.path.basename(src))
        if os.path.exists(dst):
            rep.n_dup += 1
            continue
        try:                                # corrupt harvest ≠ fatal merge
            with open(src) as f:
                json.load(f)
        except (OSError, ValueError):
            rep.n_skipped += 1
            rep.note(f"{src}: corrupt harvest file skipped")
            continue
        os.makedirs(local_dir, exist_ok=True)
        shutil.copyfile(src, dst)
        rep.n_added += 1
    return rep


def merge_workspace(local: Any, remote_root: str) -> list[MergeReport]:
    """Merge every store of the workspace at ``remote_root`` into the
    local :class:`~repro.session.workspace.Workspace`.

    Returns one :class:`MergeReport` per store.  When anything was
    actually added, a provenance entry (remote root + remote header
    identity + per-store counts) is appended to the local
    ``workspace.json`` — a no-op merge leaves the header untouched,
    which is what makes a re-merge idempotent end to end.
    """
    from repro.session.workspace import Workspace

    remote = Workspace(remote_root)
    if not os.path.isdir(remote.root):
        raise FileNotFoundError(
            f"remote workspace root {remote.root!r} does not exist")
    reports = [
        merge_jsonl(local.trace_path, remote.trace_path, store="trace"),
        merge_jsonl(local.sweep_path, remote.sweep_path, store="sweep"),
        merge_tune(local.tune_path, remote.tune_path),
        merge_bench(local.bench_dir, remote.bench_dir),
    ]
    if any(r.merged_any for r in reports):
        rh = remote.read_header()
        local.record_merge({
            "remote_root": remote.root,
            "remote_machine": rh.get("machine"),
            "remote_host": rh.get("host", {}).get("host"),
            "remote_git_sha": rh.get("git_sha"),
            "added": {r.store: r.n_added for r in reports},
            "conflicts": {r.store: r.n_conflict for r in reports
                          if r.n_conflict},
            "timestamp": time.time(),
        })
    return reports


def render_merge(reports: list[MergeReport], local_root: str,
                 remote_root: str) -> str:
    lines = [f"merge {remote_root} -> {local_root}"]
    lines += ["  " + r.describe().replace("\n", "\n  ") for r in reports]
    total = sum(r.n_added for r in reports)
    lines.append(f"  total: {total} record(s)/file(s) added"
                 + ("" if total else " (no-op)"))
    return "\n".join(lines)
