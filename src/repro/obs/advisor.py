"""Automatic bottleneck advisor: mine stored trace records for known
performance patterns and emit ranked, evidence-cited remediations.

The paper automates *characterization*; interpretation is still a human
reading roofline charts.  This module is the DeepProf direction from
PAPERS.md pointed at our own stores instead of raw GPU traces: every
rule reads only persisted state (trace/sweep records, the tune store) —
nothing is re-lowered or re-timed — so ``advise`` runs anywhere the
workspace does.

Rules (each fires one :class:`Finding` per affected record/phase):

==================  =====================================================
rule                pattern → remediation
==================  =====================================================
launch_overhead     measured wall past the serial bound with a high
                    zero-AI launch share (paper Table III census, stored
                    per phase) → ``--fusion auto`` (repro.kernels.fused)
scatter_heavy       scatter launches in a backward phase → fusion=auto
                    routes the scatter-free embedding backward
tune_mismatch       record measured under kernel configs or dispatch
                    winners that diverge from the TuneStore's current
                    state (stale_default / vanished_tuned /
                    dispatch_changed / dispatch_vanished) → re-run /
                    ``repro tune search`` / ``repro tune dispatch``
untuned             measured with every kernel at its default while the
                    tune store holds no winners for this machine →
                    ``repro tune search``
dispatch_stale      record whose ``meta.dispatch_table`` winners were
                    measured under a different git SHA or jax version
                    than the record itself → ``repro tune dispatch
                    search --force`` (tune-winner decay, first step)
level_pinned        one memory level's streaming time accounts for most
                    of the measured wall → the phase is pinned under
                    that bandwidth bound; raise arithmetic intensity
network_bound       a record's summed collective bound exceeds both its
                    memory and compute bounds → the point sits under the
                    interconnect roof (repro.net); compress / overlap
                    collectives or grow per-device work
decode_bandwidth_   a ``serve/<config>`` series whose decode-phase
regress             achieved-HBM-bandwidth fraction *drops* as the batch
                    (slot count) grows → batching is losing, not
                    gaining, bandwidth efficiency
==================  =====================================================

Findings are ranked by severity (a rule-specific 0–1+ score) and every
finding cites its evidence: run ids, phases, and the stored numbers the
rule matched on.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Iterable

#: rule names in documentation order (docs/DESIGN.md §14 table)
RULES = ("launch_overhead", "scatter_heavy", "tune_mismatch", "untuned",
         "level_pinned", "dispatch_stale", "network_bound",
         "decode_bandwidth_regress")

#: zero-AI launch share past which launch overhead is called dominant
ZERO_AI_SHARE = 0.15

#: fraction of measured wall one level's streaming time must account for
LEVEL_PIN_FRAC = 0.5

#: relative decode-bandwidth-fraction drop (vs the smaller batch) that
#: counts as a regression rather than noise
DECODE_BW_DROP = 0.05


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnosed pattern: what, where, the numbers, and the fix."""

    rule: str
    severity: float               # ranking score; higher = act sooner
    subject: str                  # "config/phase" or "config" the rule hit
    evidence: list[str]           # stored numbers the rule matched on
    remediation: str

    def describe(self) -> str:
        lines = [f"[{self.rule}] {self.subject} "
                 f"(severity {self.severity:.2f})"]
        lines += [f"    evidence: {e}" for e in self.evidence]
        lines.append(f"    fix: {self.remediation}")
        return "\n".join(lines)


def _newest_per_key(records: Iterable[Any]) -> list[Any]:
    """Newest measured record per (config, machine, host, fusion) — the
    advisor diagnoses current state, not history."""
    out: dict[tuple, Any] = {}
    for rec in sorted(records, key=lambda r: r.timestamp):
        host = rec.host.get("host", "?") if isinstance(rec.host, dict) \
            else "?"
        out[(rec.config, rec.machine, host,
             str(rec.meta.get("fusion", "off")))] = rec
    return list(out.values())


def _phase_launches(p: dict[str, Any]) -> tuple[int, int, int]:
    """(launches, zero_ai, scatter) for one stored phase payload.

    Records written since the census totals landed carry them directly;
    older records fall back to the persisted top-kernel payloads (an
    undercount — noted in the evidence by the caller via ``exact``).
    """
    if "launches" in p:
        return (int(p.get("launches", 0)),
                int(p.get("zero_ai_launches", 0)),
                int(p.get("scatter_launches", 0)))
    kernels = p.get("kernels", ())
    launches = sum(int(k.get("exec_count", 0)) for k in kernels)
    zero = sum(int(k.get("exec_count", 0)) for k in kernels
               if not float(k.get("flops", 0.0)))
    scatter = sum(int(k.get("exec_count", 0)) for k in kernels
                  if "scatter" in str(k.get("name", "")).lower())
    return launches, zero, scatter


# --------------------------------------------------------------------------
# rules
# --------------------------------------------------------------------------

def rule_launch_overhead(records: Iterable[Any]) -> list[Finding]:
    from repro.trace.timeline import timeline_from_record

    out: list[Finding] = []
    for rec in records:
        if str(rec.meta.get("fusion", "off")) != "off":
            continue                  # the remediation is already applied
        for span in timeline_from_record(rec).spans:
            if span.verdict not in ("serial", "overhead"):
                continue
            p = rec.phases.get(span.name, {})
            launches, zero, _ = _phase_launches(p)
            if not launches:
                continue
            share = zero / launches
            if share < ZERO_AI_SHARE:
                continue
            exact = "launches" in p
            over = (span.measured_s / span.bound_serial_s
                    if span.bound_serial_s else float("inf"))
            out.append(Finding(
                rule="launch_overhead",
                severity=min(over, 10.0) * share,
                subject=f"{rec.config}/{span.name}",
                evidence=[
                    f"run {rec.run_id}: {span.name} measured "
                    f"{span.measured_s * 1e3:.3f}ms vs serial bound "
                    f"{span.bound_serial_s * 1e3:.3f}ms "
                    f"({over:.2f}x, verdict {span.verdict})",
                    f"zero-AI launch share {share:.0%} "
                    f"({zero}/{launches} launches"
                    + ("" if exact else ", top-kernel estimate") + ")",
                ],
                remediation="re-record with fusion=auto "
                            "(`python -m repro record --fusion auto`) — "
                            "repro.kernels.fused collapses the zero-AI "
                            "chains this census counts"))
    return out


def rule_scatter_heavy(records: Iterable[Any]) -> list[Finding]:
    out: list[Finding] = []
    for rec in records:
        if str(rec.meta.get("fusion", "off")) != "off":
            continue
        for phase, p in rec.phases.items():
            launches, _, scatter = _phase_launches(p)
            if not scatter or phase == "fwd":
                continue              # backward/optimizer scatter only
            out.append(Finding(
                rule="scatter_heavy",
                severity=min(1.0, scatter / max(launches, 1) * 5),
                subject=f"{rec.config}/{phase}",
                evidence=[
                    f"run {rec.run_id}: {scatter} scatter launch(es) of "
                    f"{launches} in {phase}",
                ],
                remediation="set fusion=auto — the scatter-free embedding "
                            "backward (embed_with_onehot_grad) replaces "
                            "the scatter expansion with one matmul"))
    return out


def rule_tune_mismatch(records: Iterable[Any], tune_store=None,
                       machine: str = "cpu-host") -> list[Finding]:
    from repro.sweep.aggregate import tune_mismatch_rows

    kinds = {
        "stale_default": (
            0.6,
            "default {k} config, but the tune store now holds a tuned "
            "winner",
            "re-run the measurement (`python -m repro record` / "
            "`repro sweep run`) so wall times reflect the store's "
            "current best configs"),
        "vanished_tuned": (
            0.8,
            "tuned {k} config(s) that the tune store no longer has",
            "re-run `python -m repro tune search` to restore the winners "
            "this record was measured under"),
        "dispatch_changed": (
            0.6,
            "a {k} dispatch winner the store has since overturned",
            "re-run the measurement so routing reflects the current "
            "dispatch winners (`python -m repro record --fusion auto`)"),
        "dispatch_vanished": (
            0.8,
            "a {k} dispatch entry the tune store no longer holds",
            "re-run `python -m repro tune dispatch search` to restore "
            "the routing this record was measured under"),
    }
    out: list[Finding] = []
    for row in tune_mismatch_rows(list(records), tune_store,
                                  machine=machine):
        severity, what, fix = kinds[row["kind"]]
        out.append(Finding(
            rule="tune_mismatch",
            severity=severity,
            subject=f"{row['label']}/{row['kernel']}",
            evidence=[
                f"run {row['run_id']}: measured with "
                + what.format(k=row["kernel"]),
            ],
            remediation=fix))
    return out


def rule_untuned(records: Iterable[Any], tune_store=None,
                 machine: str = "cpu-host") -> list[Finding]:
    from repro.tune import tuned_kernels

    if tuned_kernels(tune_store, machine=machine):
        return []
    out: list[Finding] = []
    for rec in records:
        kcfg = rec.meta.get("kernel_configs")
        if not isinstance(kcfg, dict) or not kcfg:
            continue
        defaults = sorted(k for k, info in kcfg.items()
                          if isinstance(info, dict)
                          and info.get("source") == "default")
        if len(defaults) < len(kcfg):
            continue
        out.append(Finding(
            rule="untuned",
            severity=0.3,
            subject=rec.config,
            evidence=[
                f"run {rec.run_id}: every kernel at its default config "
                f"({', '.join(defaults)}) and the tune store has no "
                f"winners for machine {machine}",
            ],
            remediation="run `python -m repro tune search` — the PR 3 "
                        "autotuner's wins (triad 6.8x, GEMM 5.4x on the "
                        "reference host) persist per machine key"))
        break                         # one finding, not one per record
    return out


def rule_dispatch_stale(records: Iterable[Any]) -> list[Finding]:
    """Dispatch winners measured under different code/toolchain than the
    record that ran them (the first step of tune-winner decay).

    Each stamped ``meta.dispatch_table`` entry carries the git SHA and
    jax version the fused-vs-reference timing ran under; when they
    diverge from the record's own provenance, the routing decision
    predates the code that produced the wall times — the winner may have
    flipped in between.
    """
    out: list[Finding] = []
    for rec in records:
        dtab = rec.meta.get("dispatch_table")
        if not isinstance(dtab, dict) or not dtab:
            continue
        rec_sha = str(rec.git_sha or "unknown")
        rec_jax = (rec.host.get("jax", "unknown")
                   if isinstance(rec.host, dict) else "unknown")
        stale: list[str] = []
        for site, entry in sorted(dtab.items()):
            if not isinstance(entry, dict):
                continue
            e_sha = str(entry.get("git_sha", "unknown"))
            e_jax = str(entry.get("jax", "unknown"))
            drift = []
            if "unknown" not in (e_sha, rec_sha) and e_sha != rec_sha:
                drift.append(f"git {e_sha[:12]} vs {rec_sha[:12]}")
            if "unknown" not in (e_jax, rec_jax) and e_jax != rec_jax:
                drift.append(f"jax {e_jax} vs {rec_jax}")
            if drift:
                stale.append(f"{entry.get('op', site)} "
                             f"({', '.join(drift)})")
        if not stale:
            continue
        out.append(Finding(
            rule="dispatch_stale",
            severity=min(1.0, 0.4 + 0.1 * len(stale)),
            subject=rec.config,
            evidence=[
                f"run {rec.run_id}: {len(stale)} dispatch winner(s) "
                "measured under different provenance than the record: "
                + "; ".join(stale[:4])
                + ("" if len(stale) <= 4 else f"; +{len(stale) - 4} more"),
            ],
            remediation="re-measure the dispatch table on this code "
                        "(`python -m repro tune dispatch search --force`) "
                        "before trusting the routing these walls ran with"))
    return out


def rule_level_pinned(records: Iterable[Any]) -> list[Finding]:
    from repro.core.machine import MACHINES, get_machine

    out: list[Finding] = []
    for rec in records:
        machine = get_machine(rec.machine) if rec.machine in MACHINES \
            else get_machine("cpu-host")
        for phase, p in rec.phases.items():
            wall = float(p.get("wall_s", 0.0))
            if wall <= 0:
                continue
            for lv in machine.mem_levels:
                nbytes = float(p.get(f"{lv.name}_bytes", 0.0))
                if not lv.bytes_per_s or not nbytes:
                    continue
                frac = (nbytes / lv.bytes_per_s) / wall
                if frac < LEVEL_PIN_FRAC:
                    continue
                out.append(Finding(
                    rule="level_pinned",
                    severity=min(frac, 1.0),
                    subject=f"{rec.config}/{phase}",
                    evidence=[
                        f"run {rec.run_id}: {lv.name} streaming bound "
                        f"{nbytes / lv.bytes_per_s * 1e3:.3f}ms is "
                        f"{frac:.0%} of the {wall * 1e3:.3f}ms measured "
                        f"wall (dominant={p.get('dominant', '?')})",
                    ],
                    remediation=f"{phase} is pinned under the {lv.name} "
                                "bandwidth roof — raise arithmetic "
                                "intensity (larger batch/seq, AMP "
                                "O1/O2) or fuse the streaming chain "
                                "(fusion=auto)"))
    return out


def rule_network_bound(records: Iterable[Any]) -> list[Finding]:
    """Points whose collective time bound exceeds both the memory and
    compute bounds: the interconnect roof (repro.net) is the binding
    constraint.  Fires on analytical mesh-sweep points too (the bounds
    are stored whether or not the point executed) and cites the measured
    ceiling provenance stamped into ``meta.net_ceilings`` when the
    bounds came from empirical roofs."""
    # newest per point *including* mesh: each swept shape is its own
    # scaling regime and gets its own finding
    newest: dict[tuple, Any] = {}
    for rec in sorted(records, key=lambda r: r.timestamp):
        host = rec.host.get("host", "?") if isinstance(rec.host, dict) \
            else "?"
        newest[(rec.config, rec.machine, host,
                tuple(sorted((rec.mesh or {}).items())))] = rec
    out: list[Finding] = []
    for rec in newest.values():
        compute = memory = ici = dcn = 0.0
        for p in rec.phases.values():
            compute += float(p.get("compute_s", 0.0))
            memory += float(p.get("memory_s", 0.0))
            ici += float(p.get("ici_bound_s", 0.0))
            dcn += float(p.get("dcn_bound_s", 0.0))
        net = ici + dcn
        if net <= 0 or net <= max(compute, memory):
            continue
        mesh = "x".join(str(v) for _, v in sorted((rec.mesh or {}).items())) \
            or "1x1"
        evidence = [
            f"run {rec.run_id}: collective bound {net * 1e3:.3f}ms "
            f"(ici {ici * 1e3:.3f}ms + dcn {dcn * 1e3:.3f}ms) exceeds "
            f"memory {memory * 1e3:.3f}ms and compute "
            f"{compute * 1e3:.3f}ms at mesh {mesh}",
        ]
        nc = rec.meta.get("net_ceilings")
        if isinstance(nc, dict) and nc:
            for leg in sorted(nc):
                c = nc[leg] if isinstance(nc[leg], dict) else {}
                evidence.append(
                    f"{leg} ceiling {float(c.get('bytes_per_s', 0)) / 1e9:.3f}"
                    f" GB/s measured over {c.get('n_devices', '?')} "
                    f"device(s) (git {str(c.get('git_sha', '?'))[:10]}, "
                    f"tune-store key {c.get('key', '?')})")
        else:
            evidence.append(
                "bounds use datasheet interconnect ceilings — run "
                "`python -m repro net characterize` for measured roofs")
        out.append(Finding(
            rule="network_bound",
            severity=net / (net + max(compute, memory)),
            subject=f"{rec.config}@{mesh}",
            evidence=evidence,
            remediation="the point sits under the interconnect roof: cut "
                        "wire bytes (int8 gradient all-reduce — "
                        "repro.distributed.compression moves the DCN leg "
                        "to 1/4 of fp32), grow per-device work (bigger "
                        "per-device batch, smaller model axis), or stop "
                        "scaling this config past the flip point "
                        "(`python -m repro net report`)"))
    return out


def rule_decode_bandwidth_regress(records: Iterable[Any]) -> list[Finding]:
    """``serve/<config>`` series whose decode-phase achieved-HBM-bandwidth
    fraction *drops* as the batch (slot count) grows.

    Decode is bandwidth-bound; adding slots amortizes weight streaming,
    so the achieved fraction should rise (or hold) with batch.  A drop
    past :data:`DECODE_BW_DROP` means batching is losing efficiency —
    usually a scheduler regression or a KV-cache layout gone cold.
    Newest record per (config, machine, host, slots), compared along the
    slot axis.
    """
    from repro.core.machine import MACHINES, get_machine

    # newest measured decode payload per (serve key, n_slots)
    by_series: dict[tuple, dict[int, Any]] = {}
    for rec in sorted(records, key=lambda r: r.timestamp):
        if not str(rec.config).startswith("serve/"):
            continue
        p = rec.phases.get("decode")
        if not isinstance(p, dict) or float(p.get("wall_s", 0.0)) <= 0:
            continue
        slots = rec.meta.get("n_slots")
        if not isinstance(slots, int) or slots <= 0:
            continue
        host = rec.host.get("host", "?") if isinstance(rec.host, dict) \
            else "?"
        key = (rec.config, rec.machine, host,
               str(rec.meta.get("fusion", "off")))
        by_series.setdefault(key, {})[slots] = rec

    out: list[Finding] = []
    for key, by_slots in by_series.items():
        if len(by_slots) < 2:
            continue
        machine = get_machine(key[1]) if key[1] in MACHINES \
            else get_machine("cpu-host")
        fracs: list[tuple[int, float, Any]] = []
        for slots, rec in sorted(by_slots.items()):
            p = rec.phases["decode"]
            wall = float(p.get("wall_s", 0.0))
            frac = (float(p.get("hbm_bytes", 0.0)) / wall
                    / machine.hbm.bytes_per_s)
            fracs.append((slots, frac, rec))
        worst: tuple[float, Any, Any] | None = None
        for (s0, f0, r0), (s1, f1, r1) in zip(fracs, fracs[1:]):
            if f0 <= 0:
                continue
            drop = 1.0 - f1 / f0
            if drop > DECODE_BW_DROP and (worst is None
                                          or drop > worst[0]):
                worst = (drop, (s0, f0, r0), (s1, f1, r1))
        if worst is None:
            continue
        drop, (s0, f0, r0), (s1, f1, r1) = worst
        out.append(Finding(
            rule="decode_bandwidth_regress",
            severity=min(1.0, drop * 2),
            subject=f"{key[0]}/decode",
            evidence=[
                f"run {r1.run_id}: decode achieved-HBM-bandwidth fraction "
                f"{f1:.1%} at {s1} slot(s) vs {f0:.1%} at {s0} slot(s) "
                f"(run {r0.run_id}) — a {drop:.0%} drop where batching "
                "should amortize weight streaming",
            ],
            remediation="decode efficiency fell as batch grew: check the "
                        "continuous-batching scheduler (slot "
                        "fragmentation, prefill starving decode ticks) "
                        "and the KV-cache page layout; re-record with "
                        "`python -m repro serve --slots N` at both batch "
                        "sizes to bisect"))
    return out


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------

def advise(workspace: Any, config: str | None = None,
           machine: str = "cpu-host") -> list[Finding]:
    """Run every rule over the workspace's stores; ranked findings."""
    trace_recs = workspace.trace_store.records(config)
    sweep_recs = workspace.sweep_store.records(config)
    newest = _newest_per_key(trace_recs)
    stamped = [r for r in trace_recs + sweep_recs
               if isinstance(r.meta.get("kernel_configs"), dict)
               or isinstance(r.meta.get("dispatch_table"), dict)]
    tune_store = workspace.tune_store
    findings = (rule_launch_overhead(newest)
                + rule_scatter_heavy(newest)
                + rule_tune_mismatch(stamped, tune_store, machine=machine)
                + rule_untuned(stamped, tune_store, machine=machine)
                + rule_level_pinned(newest)
                + rule_dispatch_stale(stamped)
                # sweep points too: analytical mesh sweeps carry the
                # collective bounds that flag a network-bound regime
                + rule_network_bound(trace_recs + sweep_recs)
                + rule_decode_bandwidth_regress(trace_recs))
    findings.sort(key=lambda f: (-f.severity, f.rule, f.subject))
    return findings


def render_findings(findings: list[Finding], top: int = 0) -> str:
    if not findings:
        return ("advise: no known bottleneck patterns in the stored "
                "records (or no measured records yet)")
    shown = findings[:top] if top else findings
    lines = [f"advise: {len(findings)} finding(s), ranked:"]
    for i, f in enumerate(shown, 1):
        lines.append(f"{i}. " + f.describe())
    if len(findings) > len(shown):
        lines.append(f"... {len(findings) - len(shown)} more (raise --top)")
    return "\n".join(lines)
