"""``python -m repro net`` / ``python -m repro.net`` — interconnect level.

* ``characterize`` — measure this host's collective ceilings (ICI/DCN
  bandwidth + latency) over forced host devices and persist them
  machine-keyed in the workspace tune store.  A second run with the
  same machine key is a pure store hit (zero re-timing) unless
  ``--force``.
* ``report``       — stored ceilings with provenance + the mesh-scale
  ranking over persisted sweep records: which points are
  network-bound, and where each config flips.

Examples::

    PYTHONPATH=src python -m repro net characterize --devices 8 --smoke
    PYTHONPATH=src python -m repro net report --sweep netscale
"""

from __future__ import annotations

import argparse
import sys
from typing import Sequence

PROG = "python -m repro net"


def cmd_characterize(args) -> int:
    from repro.net.characterize import characterize_net
    try:
        out = characterize_net(
            args.machine, n_devices=args.devices,
            sizes=tuple(int(s) for s in args.sizes.split(","))
            if args.sizes else None,
            iters=args.iters, warmup=args.warmup, store=args.store,
            force=args.force, smoke=args.smoke,
            deadline_s=args.deadline)
    except (RuntimeError, ValueError) as e:
        print(f"net characterize: {e}", file=sys.stderr)
        return 2
    tag = "store hit — nothing re-timed" if out["cached"] else \
        f"measured over {out['n_devices']} forced host device(s)"
    print(f"net characterize: {tag} (store {out['store']})")
    from repro.net.report import ceilings_text
    print(ceilings_text(out["machine"], args.store))
    for leg, ops in sorted(out.get("ops", {}).items()):
        for op, fit in sorted(ops.items()):
            print(f"    {leg}/{op:<15} {fit['bytes_per_s'] / 1e9:8.3f} "
                  f"GB/s  lat {fit['latency_s'] * 1e6:7.1f} us")
    return 0


def cmd_report(args) -> int:
    from repro.net.report import render_net_report
    from repro.session.workspace import resolve_sweep_store
    from repro.sweep.aggregate import latest_per_point, sweep_records
    from repro.trace.store import TraceStore
    store = TraceStore(resolve_sweep_store(args.sweep_store))
    recs = latest_per_point(sweep_records(store, args.sweep))
    rows = {k: r for k, r in recs.items()
            if args.config is None or r.config == args.config}
    print(render_net_report(rows, machine=args.machine, store=args.store))
    # same contract as Session.net_report: ceilings always print, but an
    # empty ranking is a non-zero exit (nothing swept yet)
    return 0 if rows else 1


def build_parser(prog: str | None = None) -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog=prog or PROG, description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    ch = sub.add_parser("characterize",
                        help="measure collective ceilings into the "
                             "workspace tune store (store hit on re-run)")
    ch.add_argument("--machine", default="cpu-host",
                    help="machine key the ceilings are stored under")
    ch.add_argument("--devices", type=int, default=8,
                    help="forced host device count (even; default 8)")
    ch.add_argument("--sizes", default=None,
                    help="comma-separated per-device float32 elements "
                         "per sample (default: built-in sweep)")
    ch.add_argument("--iters", type=int, default=3)
    ch.add_argument("--warmup", type=int, default=1)
    ch.add_argument("--smoke", action="store_true",
                    help="small size sweep (CI preset)")
    ch.add_argument("--force", action="store_true",
                    help="re-measure even when the store already has "
                         "ceilings for this machine key")
    ch.add_argument("--store", default=None,
                    help="tune-store path (default: workspace tune.json)")
    ch.add_argument("--deadline", type=float, default=900.0,
                    help="watchdog kill deadline for the measurement "
                         "worker, seconds (default 900)")
    ch.set_defaults(fn=cmd_characterize)

    rp = sub.add_parser("report",
                        help="stored ceilings + mesh-scale network-bound "
                             "ranking over persisted sweep records")
    rp.add_argument("--machine", default="cpu-host",
                    help="machine key to read ceilings for")
    rp.add_argument("--sweep", default=None,
                    help="restrict to one campaign name")
    rp.add_argument("--config", default=None,
                    help="restrict to one registry config")
    rp.add_argument("--store", default=None,
                    help="tune-store path (default: workspace tune.json)")
    rp.add_argument("--sweep-store", default=None,
                    help="sweep-store path (default: workspace "
                         "sweep.jsonl)")
    rp.set_defaults(fn=cmd_report)
    return ap


def main(argv: Sequence[str] | None = None, prog: str | None = None) -> int:
    args = build_parser(prog).parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
