"""repro.net — the interconnect as a third roofline hierarchy level.

The paper's hierarchy stops at the device edge (VMEM → HBM); this
subsystem extends it across the wire with the same three-step
discipline every other level got:

1. **characterize** (``repro.net.characterize``): ERT-style collective
   microbenchmarks over forced host devices → empirical ICI/DCN
   bandwidth + latency ceilings, machine-keyed in the tune store;
2. **attribute** (``repro.core.hlo_analysis`` + ``repro.core.roofline``):
   compiled collectives' algorithm-corrected wire bytes land on those
   ceilings as per-phase ``net`` bounds in every trace payload;
3. **campaign** (``repro.net.report`` + the ``mesh_shapes`` sweep axis):
   sweep mesh shapes and ask where each config flips from HBM-bound to
   network-bound.

``python -m repro net {characterize,report}`` is the CLI; see
docs/DESIGN.md §18.
"""

from repro.net.characterize import (characterize_net, machine_with_net,
                                    net_ceilings)
from repro.net.collectives import (LEGS, OPS, fit_ceiling,
                                   measure_collectives, payload_bytes,
                                   wire_bytes)

__all__ = [
    "LEGS", "OPS", "characterize_net", "fit_ceiling", "machine_with_net",
    "measure_collectives", "net_ceilings", "payload_bytes", "wire_bytes",
]
