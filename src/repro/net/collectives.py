"""Collective microbenchmarks: the ERT discipline applied to the wire.

Each benchmark times one collective primitive (all-reduce / all-gather /
reduce-scatter / all-to-all) over a ring of forced host devices across a
sweep of message sizes, exactly the way ``repro.kernels.ert`` times the
FMA chain and triad across working-set sizes.  The *wire* bytes of each
sample use the same algorithm-corrected ring formulas
``core/hlo_analysis.py`` applies to compiled collectives
(all-reduce ``2(n-1)/n``, all-gather/reduce-scatter/all-to-all
``(n-1)/n``), so the measured ceiling and the attributed traffic live in
the same unit.

Two legs mirror the ICI/DCN split:

* ``ici`` — the collective runs over the full device ring (one "pod");
* ``dcn`` — the devices are split into two "pods" and the collective
  runs over the pod axis only (the cross-pod leg
  ``distributed/compression.py`` optimizes).  On a forced-host ring both
  legs traverse the same silicon — the point is exercising the
  characterize→store→attribute discipline end to end, so a real
  multi-pod deployment only swaps the mesh (docs/DESIGN.md §18).

This module imports jax lazily: it is shipped to a *spawned* worker whose
initializer pins ``--xla_force_host_platform_device_count`` before the
first jax import (the same harness the sweep engine uses).
"""

from __future__ import annotations

from typing import Any

#: benchmarked collective primitives, in report order
OPS = ("all_reduce", "all_gather", "reduce_scatter", "all_to_all")
#: interconnect legs, fastest first (matches MachineSpec.interconnect)
LEGS = ("ici", "dcn")


def wire_bytes(op: str, payload_bytes: float, group_size: int) -> float:
    """Ring-algorithm wire bytes for one collective execution.

    Mirrors ``core/hlo_analysis._COLL_MULT`` (including the
    ``max(group_size, 2)`` floor) so measured ceilings divide the same
    quantity the HLO walk attributes.
    """
    n = max(group_size, 2)
    if op == "all_reduce":
        return 2.0 * (n - 1) / n * payload_bytes
    if op in ("all_gather", "reduce_scatter", "all_to_all"):
        return (n - 1) / n * payload_bytes
    return float(payload_bytes)


def payload_bytes(op: str, elems_per_device: int, group_size: int,
                  itemsize: int = 4) -> float:
    """Payload of one collective, in the HLO walk's convention.

    all-reduce keys on the (replicated) result, all-gather on its n×
    output, reduce-scatter / all-to-all on the larger (input) side.
    """
    if op == "all_gather":
        return float(group_size * elems_per_device * itemsize)
    return float(elems_per_device * itemsize)


def _collective_fns(n_devices: int, leg: str):
    """{op: jitted collective over the leg's mesh axis} + the group size.

    ``ici`` runs over the full ring; ``dcn`` splits the ring into two
    pods and runs over the pod axis (group size 2).
    """
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P

    from repro.core.compat import shard_map

    if leg == "dcn":
        if n_devices % 2:
            raise ValueError(f"dcn leg needs an even device count, "
                             f"got {n_devices}")
        mesh = jax.make_mesh((2, n_devices // 2), ("pod", "x"))
        axis, gsize = "pod", 2
        in_spec = P("pod")
    else:
        mesh = jax.make_mesh((n_devices,), ("x",))
        axis, gsize = "x", n_devices
        in_spec = P("x")

    def wrap(body, out_spec):
        # check_rep=False: replication inference for collectives varies
        # across jax versions; the outputs here are structurally correct
        fn = shard_map(body, mesh=mesh, in_specs=(in_spec,),
                       out_specs=out_spec, check_rep=False)
        return jax.jit(fn)

    fns = {
        "all_reduce": wrap(lambda x: lax.psum(x, axis), P()),
        "all_gather": wrap(lambda x: lax.all_gather(x, axis, tiled=True),
                           P()),
        "reduce_scatter": wrap(lambda x: lax.psum_scatter(x, axis,
                                                          tiled=True),
                               in_spec),
        "all_to_all": wrap(lambda x: lax.all_to_all(x, axis, 0, 0,
                                                    tiled=True),
                           in_spec),
    }
    return fns, gsize, jnp


def measure_collectives(n_devices: int, sizes: tuple[int, ...],
                        iters: int = 3, warmup: int = 1,
                        legs: tuple[str, ...] = LEGS
                        ) -> list[dict[str, Any]]:
    """Time every (leg × op × size) sample on this process's devices.

    ``sizes`` are per-device elements (float32); each must be divisible
    by the group size so tiled reduce-scatter / all-to-all lower cleanly.
    Returns one row per sample: ``{leg, op, group_size, elems,
    payload_bytes, wire_bytes, t_s}`` with ``t_s`` the min-of-samples
    wall time (ceiling discipline: noise only ever adds time).
    """
    import time

    import jax

    if jax.device_count() < n_devices:
        raise RuntimeError(
            f"collective characterization needs {n_devices} devices but "
            f"this process has {jax.device_count()} — run through the "
            "sweep engine's worker harness (it pins the XLA host-device "
            "count), not inline")
    rows: list[dict[str, Any]] = []
    for leg in legs:
        fns, gsize, jnp = _collective_fns(n_devices, leg)
        for op in OPS:
            fn = fns[op]
            for elems in sizes:
                if elems % max(gsize, 1):
                    continue
                x = jnp.ones((n_devices * elems,), jnp.float32)
                out = None
                for _ in range(max(warmup, 1)):
                    out = fn(x)
                jax.block_until_ready(out)
                best = float("inf")
                for _ in range(max(iters, 1)):
                    t0 = time.perf_counter()
                    out = fn(x)
                    jax.block_until_ready(out)
                    best = min(best, time.perf_counter() - t0)
                pay = payload_bytes(op, elems, gsize)
                rows.append({
                    "leg": leg, "op": op, "group_size": gsize,
                    "elems": elems, "payload_bytes": pay,
                    "wire_bytes": wire_bytes(op, pay, gsize),
                    "t_s": best,
                })
    return rows


def fit_ceiling(samples: list[tuple[float, float]]
                ) -> tuple[float, float]:
    """(bytes_per_s, latency_s) from (wire_bytes, seconds) samples.

    Least-squares fit of ``t = latency + wire / bw`` — the classic
    alpha-beta collective model.  Degenerate fits (noise producing a
    non-positive slope) fall back to the best observed throughput with
    zero latency, so the stored ceiling is never nonsense.
    """
    if not samples:
        raise ValueError("no samples to fit")
    n = len(samples)
    sx = sum(w for w, _ in samples)
    sy = sum(t for _, t in samples)
    sxx = sum(w * w for w, _ in samples)
    sxy = sum(w * t for w, t in samples)
    denom = n * sxx - sx * sx
    slope = (n * sxy - sx * sy) / denom if denom else 0.0
    intercept = (sy - slope * sx) / n
    if slope <= 0:
        return max(w / t for w, t in samples if t > 0), 0.0
    return 1.0 / slope, max(intercept, 0.0)
