"""Net reporting: stored interconnect ceilings + mesh-scale ranking.

Store-only, like every report surface in this repo: the ceilings come
from the tune store (``repro.net.characterize`` put them there) and the
campaign rows from persisted sweep records — nothing is re-lowered or
re-timed.  The question this report answers is the tentpole's: *at what
mesh shape does each config flip from HBM-bound to network-bound?*

Every stored phase payload carries the interconnect level
(``ici_bytes`` / ``dcn_bytes`` / ``ici_bound_s`` / ``dcn_bound_s``, see
``repro.trace.store.phase_payload``), so classification is pure
arithmetic over stored numbers: a point is **network-bound** when its
summed collective time bound exceeds both its memory and compute
bounds.
"""

from __future__ import annotations

import time
from typing import Any, Mapping, Sequence

from repro.net.collectives import LEGS


def ceilings_text(machine: str = "cpu-host", store: Any = None) -> str:
    """The stored empirical ceilings, with provenance — or the datasheet
    fallback note when this machine key was never characterized."""
    from repro.net.characterize import net_ceilings
    ceil = net_ceilings(machine, store)
    lines = [f"interconnect ceilings (machine {machine}):"]
    if ceil is None:
        from repro.core.machine import MACHINES
        spec = MACHINES.get(machine)
        if spec is None:
            return f"interconnect ceilings: unknown machine {machine!r}"
        for lv in spec.interconnect:
            lines.append(f"  {lv.name:<4} {lv.bytes_per_s / 1e9:8.2f} GB/s"
                         "  (datasheet — run `python -m repro net "
                         "characterize` for measured roofs)")
        return "\n".join(lines)
    for leg in LEGS:
        c = ceil[leg]
        age = time.strftime("%Y-%m-%d", time.localtime(c["timestamp"]))
        lines.append(
            f"  {leg:<4} {c['bytes_per_s'] / 1e9:8.3f} GB/s  "
            f"lat {c['latency_s'] * 1e6:7.1f} us  "
            f"(measured, {c['n_devices']} device(s), {age}, "
            f"git {str(c['git_sha'])[:10]})")
    return "\n".join(lines)


# --------------------------------------------------------------------------
# mesh-campaign rows
# --------------------------------------------------------------------------

def net_row(rec: Any) -> dict[str, Any]:
    """Fold one record's phases into an interconnect-level summary row."""
    sums = {k: 0.0 for k in ("compute_s", "memory_s", "ici_s", "dcn_s",
                             "wall_s", "net_bytes")}
    for p in rec.phases.values():
        sums["compute_s"] += float(p.get("compute_s", 0.0))
        sums["memory_s"] += float(p.get("memory_s", 0.0))
        sums["ici_s"] += float(p.get("ici_bound_s", 0.0))
        sums["dcn_s"] += float(p.get("dcn_bound_s", 0.0))
        sums["wall_s"] += float(p.get("wall_s", 0.0))
        sums["net_bytes"] += float(p.get("net_bytes", 0.0))
    net_s = sums["ici_s"] + sums["dcn_s"]
    terms = {"compute": sums["compute_s"], "mem": sums["memory_s"],
             "net": net_s}
    mesh = dict(rec.mesh or {})
    n_devices = 1
    for v in mesh.values():
        n_devices *= max(int(v), 1)
    return {
        "config": rec.config,
        "label": str(rec.meta.get("label") or rec.config),
        "mesh": mesh,
        "n_devices": n_devices,
        "bound": max(terms, key=terms.get),
        "net_s": net_s,
        "step_bound_s": max(terms.values()),
        "net_frac": (net_s / max(terms.values())
                     if max(terms.values()) else 0.0),
        "run_id": rec.run_id,
        **sums,
    }


def net_rows(records: Sequence[Any] | Mapping[str, Any]
             ) -> list[dict[str, Any]]:
    """One row per point, configs together, smallest mesh first — the
    scale axis the flip detector walks."""
    recs = list(records.values() if isinstance(records, Mapping)
                else records)
    rows = [net_row(r) for r in recs]
    rows.sort(key=lambda r: (r["config"], r["n_devices"],
                             sorted(r["mesh"].items())))
    return rows


def _mesh_label(mesh: Mapping[str, int]) -> str:
    if not mesh:
        return "1x1"
    return "x".join(str(mesh[k]) for k in sorted(mesh))


def flip_lines(rows: Sequence[Mapping[str, Any]]) -> list[str]:
    """Per config: where (if anywhere) along the mesh-scale axis the
    binding constraint flips to the network."""
    by_cfg: dict[str, list[Mapping[str, Any]]] = {}
    for r in rows:
        by_cfg.setdefault(r["config"], []).append(r)
    out: list[str] = []
    for cfg, rs in sorted(by_cfg.items()):
        flip = next((r for r in rs if r["bound"] == "net"), None)
        if flip is None:
            worst = max(rs, key=lambda r: r["net_frac"])
            out.append(
                f"{cfg}: never network-bound over the swept shapes "
                f"(closest: mesh {_mesh_label(worst['mesh'])} at "
                f"{worst['net_frac']:.0%} of its binding term)")
        elif flip is rs[0]:
            out.append(
                f"{cfg}: network-bound at every swept shape (already at "
                f"mesh {_mesh_label(flip['mesh'])}: net "
                f"{flip['net_s'] * 1e3:.3f}ms vs mem "
                f"{flip['memory_s'] * 1e3:.3f}ms)")
        else:
            prev = rs[rs.index(flip) - 1]
            out.append(
                f"{cfg}: flips {prev['bound']}-bound -> network-bound at "
                f"mesh {_mesh_label(flip['mesh'])} "
                f"(net {flip['net_s'] * 1e3:.3f}ms > mem "
                f"{flip['memory_s'] * 1e3:.3f}ms; at mesh "
                f"{_mesh_label(prev['mesh'])} it was "
                f"{prev['net_frac']:.0%})")
    return out


def render_net_report(records: Sequence[Any] | Mapping[str, Any],
                      machine: str = "cpu-host",
                      store: Any = None) -> str:
    """Ceilings + the ranked mesh-scale table + per-config flip lines."""
    parts = [ceilings_text(machine, store)]
    rows = net_rows(records)
    if not rows:
        parts.append("(no stored records with interconnect payloads — "
                     "run a sweep with mesh_shapes first)")
        return "\n\n".join(parts)
    ranked = sorted(rows, key=lambda r: r["step_bound_s"])
    header = (f"{'#':>2} {'point':<38}{'mesh':<8}{'dev':>4} "
              f"{'compute':>9} {'mem':>9} {'ici':>9} {'dcn':>9} "
              f"{'net%':>5}  bound")
    lines = [header]
    for i, r in enumerate(ranked, 1):
        lines.append(
            f"{i:>2} {r['label'][:37]:<38}"
            f"{_mesh_label(r['mesh']):<8}{r['n_devices']:>4} "
            f"{r['compute_s'] * 1e3:>8.3f}m {r['memory_s'] * 1e3:>8.3f}m "
            f"{r['ici_s'] * 1e3:>8.3f}m {r['dcn_s'] * 1e3:>8.3f}m "
            f"{r['net_frac']:>5.0%}  {r['bound']}")
    parts.append("mesh-scale ranking (best step bound first):\n"
                 + "\n".join(lines))
    parts.append("\n".join(flip_lines(rows)))
    return "\n\n".join(parts)
