"""Interconnect characterization: measured collective ceilings → TuneStore.

The driver half of the net subsystem (docs/DESIGN.md §18).  The worker
half (``repro.net.collectives``) times ring collectives over forced host
devices; this module runs it through the same :class:`SupervisedPool` +
``_worker_init`` harness the sweep engine uses (XLA's device count is
fixed at jax import, so the measurement always happens in a spawned
worker), fits the alpha-beta model per (leg, op), and persists the
ceilings machine-keyed in the tune store right next to the kernel
ceilings:

* one record per (leg, op): ``kernel="net_<leg>_<op>"``, shape
  ``[n_devices]`` — the raw evidence;
* one summary record per leg: ``kernel="net_ici"`` / ``"net_dcn"``,
  shape ``[0]`` (the "any shape" sentinel, same convention as the ERT
  ceiling records) — what :func:`machine_with_net` folds into a
  :class:`~repro.core.machine.MachineSpec`.

Store discipline matches ``repro.tune``: a second characterization of
the same machine key is a pure store hit — zero re-timing — unless
``force=True``.

Import-light: jax, the pool and the stores all load inside functions
(worker processes import this module before fixing their device count).
"""

from __future__ import annotations

from typing import Any, Mapping

from repro.net.collectives import LEGS, OPS, measure_collectives

#: backend tag net records carry in their tune keys
NET_BACKEND = "collective"
#: dtype every collective sample uses (float32 payloads)
NET_DTYPE = "float32"
#: shape sentinel for the per-leg summary records ("any shape")
SUMMARY_SHAPE = (0,)

#: per-device float32 elements per sample (divisible by ring sizes 2..8)
DEFAULT_SIZES = (1024, 8192, 65536, 262144)
SMOKE_SIZES = (1024, 16384, 131072)
DEFAULT_DEVICES = 8


def summary_key(leg: str, machine: str) -> str:
    from repro.tune.store import tune_key
    return tune_key(f"net_{leg}", SUMMARY_SHAPE, NET_DTYPE, machine,
                    backend=NET_BACKEND)


def net_ceilings(machine: Any, store: Any = None
                 ) -> dict[str, dict[str, Any]] | None:
    """Stored empirical interconnect ceilings for one machine key.

    ``{"ici": {bytes_per_s, latency_s, n_devices, key, timestamp,
    git_sha}, "dcn": {...}}`` — or ``None`` when either leg is missing
    (consumers fall back to the datasheet numbers, exactly like an
    untuned kernel falls back to its default config).
    """
    from repro.tune.store import _as_store
    name = machine if isinstance(machine, str) else machine.name
    store = _as_store(store)
    out: dict[str, dict[str, Any]] = {}
    for leg in LEGS:
        rec = store.get(summary_key(leg, name))
        if rec is None:
            return None
        out[leg] = {
            "bytes_per_s": float(rec.params.get("bytes_per_s", rec.metric)),
            "latency_s": float(rec.params.get("latency_s", 0.0)),
            "n_devices": int(rec.params.get("n_devices", 0)),
            "key": rec.key,
            "timestamp": rec.timestamp,
            "git_sha": rec.git_sha,
        }
    return out


def machine_with_net(machine: Any, store: Any = None):
    """The machine spec, with stored net ceilings folded in when present.

    The one resolution rule every attribution path shares (sweep engine,
    ``Session.record``): measured interconnect roofs when the store has
    them, datasheet otherwise — never a mix of legs.
    """
    from repro.core.machine import get_machine
    spec = get_machine(machine) if isinstance(machine, str) else machine
    ceil = net_ceilings(spec.name, store)
    if not ceil:
        return spec
    return spec.with_empirical_net(
        {leg: c["bytes_per_s"] for leg, c in ceil.items()},
        {leg: c["latency_s"] for leg, c in ceil.items()})


# --------------------------------------------------------------------------
# measurement driver
# --------------------------------------------------------------------------

def _measure_job(n_devices: int, sizes: tuple, iters: int, warmup: int
                 ) -> dict:
    """Worker entry (picklable, module scope): measure, return rows."""
    import traceback
    try:
        rows = measure_collectives(n_devices, tuple(sizes),
                                   iters=iters, warmup=warmup)
    except Exception:
        return {"error": traceback.format_exc()}
    return {"rows": rows}


def _datasheet_bw(machine: str) -> dict[str, float]:
    from repro.core.machine import MACHINES
    spec = MACHINES.get(machine)
    if spec is None:
        return {}
    return {lv.name: lv.bytes_per_s for lv in spec.interconnect}


def _fit_rows(rows: list[Mapping[str, Any]]
              ) -> dict[tuple[str, str], dict[str, Any]]:
    """(leg, op) → fitted ceiling + the samples behind it."""
    from repro.net.collectives import fit_ceiling
    grouped: dict[tuple[str, str], list[Mapping[str, Any]]] = {}
    for r in rows:
        grouped.setdefault((r["leg"], r["op"]), []).append(r)
    out: dict[tuple[str, str], dict[str, Any]] = {}
    for key, rs in grouped.items():
        bw, lat = fit_ceiling([(r["wire_bytes"], r["t_s"]) for r in rs])
        out[key] = {"bytes_per_s": bw, "latency_s": lat,
                    "group_size": int(rs[0]["group_size"]),
                    "n_samples": len(rs),
                    "wall_s": max(float(r["t_s"]) for r in rs)}
    return out


def _persist(fits: Mapping[tuple[str, str], Mapping[str, Any]],
             machine: str, n_devices: int, sizes: tuple,
             store: Any) -> dict[str, dict[str, Any]]:
    """Per-op + per-leg summary records into the tune store (one atomic
    write), returning the fresh :func:`net_ceilings` view."""
    from repro.tune.store import make_record
    datasheet = _datasheet_bw(machine)
    recs = {}
    per_leg: dict[str, dict[str, Any]] = {}
    for (leg, op), fit in sorted(fits.items()):
        rec = make_record(
            kernel=f"net_{leg}_{op}", shape=(n_devices,), dtype=NET_DTYPE,
            machine=machine, backend=NET_BACKEND,
            params={"leg": leg, "op": op,
                    "bytes_per_s": fit["bytes_per_s"],
                    "latency_s": fit["latency_s"],
                    "group_size": fit["group_size"],
                    "sizes": [int(s) for s in sizes]},
            wall_s=fit["wall_s"], metric=fit["bytes_per_s"],
            metric_name="wire_bytes_per_s", default_wall_s=0.0,
            default_metric=datasheet.get(leg, 0.0),
            n_candidates=fit["n_samples"])
        recs[rec.key] = rec.to_dict()
        per_leg.setdefault(leg, {})[op] = {
            "bytes_per_s": fit["bytes_per_s"],
            "latency_s": fit["latency_s"]}
    for leg, ops in per_leg.items():
        # the *ceiling* of a leg is the best throughput any collective
        # achieved over it (ERT discipline: roofs are attainable maxima),
        # with the smallest fitted launch latency — an optimistic bound,
        # so attributed collective time stays a lower bound on the truth
        bw = max(o["bytes_per_s"] for o in ops.values())
        lat = min(o["latency_s"] for o in ops.values())
        rec = make_record(
            kernel=f"net_{leg}", shape=SUMMARY_SHAPE, dtype=NET_DTYPE,
            machine=machine, backend=NET_BACKEND,
            params={"leg": leg, "bytes_per_s": bw, "latency_s": lat,
                    "n_devices": n_devices, "ops": ops,
                    "sizes": [int(s) for s in sizes]},
            wall_s=0.0, metric=bw, metric_name="wire_bytes_per_s",
            default_wall_s=0.0, default_metric=datasheet.get(leg, 0.0),
            n_candidates=len(ops))
        recs[rec.key] = rec.to_dict()
    store.put_many(recs)
    ceil = net_ceilings(machine, store)
    assert ceil is not None
    return ceil


def characterize_net(machine: Any = "cpu-host", *,
                     n_devices: int = DEFAULT_DEVICES,
                     sizes: tuple | None = None,
                     iters: int = 3, warmup: int = 1,
                     store: Any = None, force: bool = False,
                     smoke: bool = False, deadline_s: float = 900.0,
                     inline: bool = False) -> dict[str, Any]:
    """Measure (or fetch) this host's interconnect ceilings.

    Returns ``{machine, n_devices, ceilings, ops, cached, store}``.
    ``cached=True`` means both per-leg summaries were already stored
    under this machine key and **nothing was re-timed**.  ``inline=True``
    measures in this process (the caller must already have enough
    devices — tests force the count before importing jax); the default
    spawns one supervised worker that pins
    ``--xla_force_host_platform_device_count`` first, exactly like a
    sweep point.
    """
    from repro.tune.store import _as_store
    name = machine if isinstance(machine, str) else machine.name
    store = _as_store(store)

    if not force:
        cached = net_ceilings(name, store)
        if cached is not None:
            return {"machine": name, "n_devices": n_devices,
                    "ceilings": cached, "ops": {}, "cached": True,
                    "store": store.path}

    if sizes is None:
        sizes = SMOKE_SIZES if smoke else DEFAULT_SIZES
    sizes = tuple(int(s) for s in sizes)
    if n_devices % 2:
        raise ValueError(f"n_devices must be even (the dcn leg splits the "
                         f"ring into two pods), got {n_devices}")

    if inline:
        rows = measure_collectives(n_devices, sizes, iters=iters,
                                   warmup=warmup)
    else:
        from repro.resilience.watchdog import SupervisedPool
        from repro.sweep.engine import _worker_init
        with SupervisedPool(_measure_job, 1, init=_worker_init,
                            initargs=(n_devices,),
                            deadline_s=deadline_s) as pool:
            outcomes = pool.run(
                [("net", (n_devices, sizes, iters, warmup))])
        out = outcomes["net"]
        value = out.value if out.ok else None
        if value is None or value.get("error"):
            err = (value or {}).get("error") or out.error or out.kind
            raise RuntimeError(
                f"collective characterization failed ({out.kind}): {err}")
        rows = value["rows"]

    fits = _fit_rows(rows)
    ceilings = _persist(fits, name, n_devices, sizes, store)
    ops = {}
    for (leg, op), fit in sorted(fits.items()):
        ops.setdefault(leg, {})[op] = {
            "bytes_per_s": fit["bytes_per_s"],
            "latency_s": fit["latency_s"]}
    return {"machine": name, "n_devices": n_devices, "ceilings": ceilings,
            "ops": ops, "cached": False, "store": store.path}
