"""Sharded, atomic, mesh-independent checkpointing (tensorstore-free).

Design goals (task spec §fault tolerance):

* **atomic commit** — writes go to ``step_XXXX.tmp/``, then a single
  ``rename`` publishes the directory and ``latest`` is rewritten last;
  a crash mid-write can never corrupt the restore path.
* **mesh-independent** — arrays are saved fully-addressable (gathered to
  host), so a restart may load onto a *different* mesh (elastic re-scale):
  ``restore(..., shardings=...)`` re-shards on load.
* **async** — ``save_async`` snapshots to host memory synchronously (cheap
  vs device compute) and writes files on a background thread, overlapping
  I/O with the next training steps.
* **self-describing** — a ``manifest.json`` stores the tree structure,
  per-leaf dtype/shape, plus user metadata (step, data offset, RNG state),
  everything a restart needs.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

_MANIFEST = "manifest.json"
_LATEST = "latest"


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(_key_str(k) for k in path) or "root"
        out.append((name, leaf))
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def save(directory: str, step: int, tree: Any,
         metadata: dict | None = None) -> str:
    """Synchronous atomic save. Returns the committed directory."""
    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    return _write(directory, step, host_tree, metadata or {})


class AsyncCheckpointer:
    """Snapshot synchronously, write on a background thread."""

    def __init__(self) -> None:
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, directory: str, step: int, tree: Any,
             metadata: dict | None = None) -> None:
        self.wait()                                       # one write in flight
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            try:
                _write(directory, step, host_tree, metadata or {})
            except BaseException as e:                    # surfaced on wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err


def _write(directory: str, step: int, host_tree: Any, metadata: dict) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = _leaf_paths(host_tree)
    manifest = {"step": step, "metadata": metadata, "leaves": {}}
    arrays = {}
    for name, leaf in leaves:
        arr = np.asarray(leaf)
        key = name.replace("/", "__")
        arrays[key] = arr
        manifest["leaves"][name] = {"key": key, "dtype": str(arr.dtype),
                                    "shape": list(arr.shape)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                                  # atomic publish
    with open(os.path.join(directory, _LATEST + ".tmp"), "w") as f:
        f.write(os.path.basename(final))
    os.replace(os.path.join(directory, _LATEST + ".tmp"),
               os.path.join(directory, _LATEST))
    _gc(directory, keep=3)
    return final


def _gc(directory: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> int | None:
    try:
        with open(os.path.join(directory, _LATEST)) as f:
            name = f.read().strip()
        return int(name.removeprefix("step_"))
    except (FileNotFoundError, ValueError):
        return None


def restore(directory: str, tree_like: Any, step: int | None = None,
            shardings: Any = None) -> tuple[Any, dict]:
    """Load a checkpoint into the structure of ``tree_like``.

    ``shardings`` (optional pytree of NamedSharding, same structure) re-shards
    each leaf for the *current* mesh — the elastic-rescale path: a checkpoint
    written on 256 chips restores cleanly onto 512 or 64.
    Returns (tree, metadata).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    src = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(src, _MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(src, "arrays.npz"))

    names = [n for n, _ in _leaf_paths(tree_like)]
    flat_like, treedef = jax.tree.flatten(tree_like)
    shard_flat = (jax.tree.leaves(shardings)
                  if shardings is not None else [None] * len(flat_like))
    out = []
    for name, like, shd in zip(names, flat_like, shard_flat):
        info = manifest["leaves"][name]
        arr = data[info["key"]]
        dtype = getattr(like, "dtype", arr.dtype)
        arr = arr.astype(dtype)
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.device_put(arr))
    return treedef.unflatten(out), manifest["metadata"]
