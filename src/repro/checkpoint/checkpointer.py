"""Sharded, atomic, mesh-independent checkpointing (tensorstore-free).

Design goals (task spec §fault tolerance):

* **atomic commit** — writes go to ``step_XXXX.tmp/``, then a single
  ``rename`` publishes the directory and ``latest`` is rewritten last;
  a crash mid-write can never corrupt the restore path.
* **mesh-independent** — arrays are saved fully-addressable (gathered to
  host), so a restart may load onto a *different* mesh (elastic re-scale):
  ``restore(..., shardings=...)`` re-shards on load.
* **async** — ``save_async`` snapshots to host memory synchronously (cheap
  vs device compute) and writes files on a background thread, overlapping
  I/O with the next training steps; ``healthy()`` lets the training loop
  notice a dead writer without blocking on the next save.
* **self-describing** — a ``manifest.json`` stores the tree structure,
  per-leaf dtype/shape, plus user metadata (step, data offset, RNG state),
  everything a restart needs.
* **verified** — the manifest carries a sha256 digest over every leaf's
  name, dtype, shape and bytes; ``restore`` recomputes it and raises
  :class:`CheckpointCorrupt` on mismatch, so a truncated or bit-flipped
  checkpoint is rejected instead of silently training from garbage.
  GC never deletes the directory ``latest`` points to, so a concurrent
  restore that just resolved ``latest`` cannot lose its target.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

from repro.resilience import faults

_MANIFEST = "manifest.json"
_LATEST = "latest"


class CheckpointCorrupt(RuntimeError):
    """A checkpoint failed its integrity check (digest mismatch)."""


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(_key_str(k) for k in path) or "root"
        out.append((name, leaf))
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def _tree_digest(named_arrays: list[tuple[str, np.ndarray]]) -> str:
    """sha256 over (name, dtype, shape, bytes) of every leaf, in sorted
    name order — the save-time fingerprint ``restore`` verifies."""
    h = hashlib.sha256()
    for name, arr in sorted(named_arrays, key=lambda t: t[0]):
        h.update(name.encode())
        h.update(str(arr.dtype).encode())
        h.update(repr(tuple(arr.shape)).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def save(directory: str, step: int, tree: Any,
         metadata: dict | None = None, keep: int = 3) -> str:
    """Synchronous atomic save. Returns the committed directory.

    ``keep`` bounds how many committed checkpoints GC retains
    (0 = never collect).
    """
    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    return _write(directory, step, host_tree, metadata or {}, keep)


class AsyncCheckpointer:
    """Snapshot synchronously, write on a background thread."""

    def __init__(self, keep: int = 3) -> None:
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, directory: str, step: int, tree: Any,
             metadata: dict | None = None, keep: int | None = None) -> None:
        self.wait()                                       # one write in flight
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        keep_n = self.keep if keep is None else keep

        def work():
            try:
                _write(directory, step, host_tree, metadata or {}, keep_n)
            except BaseException as e:                    # surfaced on wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def healthy(self) -> bool:
        """True while no background write has failed.  Non-blocking: the
        training loop polls this each log interval so a dead checkpointer
        fails the run promptly instead of at the *next* save attempt."""
        return self._error is None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err


def _write(directory: str, step: int, host_tree: Any, metadata: dict,
           keep: int = 3) -> str:
    faults.active_plan().maybe_raise("ckpt_fail", target=step,
                                    exc=faults.InjectedFault)
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = _leaf_paths(host_tree)
    manifest = {"step": step, "metadata": metadata, "leaves": {}}
    arrays = {}
    named = []
    for name, leaf in leaves:
        arr = np.asarray(leaf)
        key = name.replace("/", "__")
        arrays[key] = arr
        named.append((name, arr))
        manifest["leaves"][name] = {"key": key, "dtype": str(arr.dtype),
                                    "shape": list(arr.shape)}
    manifest["digest"] = _tree_digest(named)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                                  # atomic publish
    with open(os.path.join(directory, _LATEST + ".tmp"), "w") as f:
        f.write(os.path.basename(final))
    os.replace(os.path.join(directory, _LATEST + ".tmp"),
               os.path.join(directory, _LATEST))
    _gc(directory, keep=keep)
    return final


def _gc(directory: str, keep: int) -> None:
    """Collect old ``step_*`` dirs down to ``keep`` (0 disables GC).

    The directory ``latest`` points to is always protected, even when it
    is not among the newest ``keep``: a restore that resolved ``latest``
    a moment ago must still find its target on disk.
    """
    if keep <= 0:
        return
    try:
        with open(os.path.join(directory, _LATEST)) as f:
            pointed = f.read().strip()
    except OSError:
        pointed = ""
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        if d == pointed:
            continue
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> int | None:
    try:
        with open(os.path.join(directory, _LATEST)) as f:
            name = f.read().strip()
        return int(name.removeprefix("step_"))
    except (FileNotFoundError, ValueError):
        return None


def available_steps(directory: str) -> list[int]:
    """Committed checkpoint steps on disk, oldest first — the fallback
    ladder a restore walks when the newest checkpoint fails its digest."""
    try:
        names = os.listdir(directory)
    except OSError:
        return []
    out = []
    for d in names:
        if d.startswith("step_") and not d.endswith(".tmp"):
            try:
                out.append(int(d.removeprefix("step_")))
            except ValueError:
                continue
    return sorted(out)


def restore(directory: str, tree_like: Any, step: int | None = None,
            shardings: Any = None, verify: bool = True) -> tuple[Any, dict]:
    """Load a checkpoint into the structure of ``tree_like``.

    ``shardings`` (optional pytree of NamedSharding, same structure) re-shards
    each leaf for the *current* mesh — the elastic-rescale path: a checkpoint
    written on 256 chips restores cleanly onto 512 or 64.
    ``verify`` recomputes the manifest digest over the loaded arrays and
    raises :class:`CheckpointCorrupt` on mismatch (manifests predating the
    digest field pass unverified).  Returns (tree, metadata).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    src = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(src, _MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(src, "arrays.npz"))

    if verify and "digest" in manifest:
        named = [(name, np.asarray(data[info["key"]]))
                 for name, info in manifest["leaves"].items()]
        got = _tree_digest(named)
        if got != manifest["digest"]:
            raise CheckpointCorrupt(
                f"{src}: digest mismatch (manifest "
                f"{manifest['digest'][:12]}…, arrays {got[:12]}…) — "
                "checkpoint rejected")

    names = [n for n, _ in _leaf_paths(tree_like)]
    flat_like, treedef = jax.tree.flatten(tree_like)
    shard_flat = (jax.tree.leaves(shardings)
                  if shardings is not None else [None] * len(flat_like))
    out = []
    for name, like, shd in zip(names, flat_like, shard_flat):
        info = manifest["leaves"][name]
        arr = data[info["key"]]
        dtype = getattr(like, "dtype", arr.dtype)
        arr = arr.astype(dtype)
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.device_put(arr))
    return treedef.unflatten(out), manifest["metadata"]
