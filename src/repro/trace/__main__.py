"""Deprecated entry point — ``python -m repro {record,compare,report}``
is the unified surface (same flags, same output, one workspace)."""

import sys

from repro.trace.cli import main

if __name__ == "__main__":
    print("note: `python -m repro.trace` is deprecated; use "
          "`python -m repro {record,compare,report}` (same flags, "
          "one REPRO_WORKSPACE root — see docs/CLI.md)", file=sys.stderr)
    sys.exit(main())
