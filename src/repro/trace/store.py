"""Append-only JSONL results store for measured trace runs.

One line per run, schema-versioned (``schema_version``) so records written
by older code stay readable as the format grows (automated collection +
persistence workflow in the spirit of arXiv 2009.02449).  Run metadata
binds every record to its provenance: git SHA, host fingerprint, machine
model, config name and mesh — enough to answer "what changed?" when
``repro.trace.compare`` flags a regression between two commits.

The store is deliberately boring: plain JSONL, append-only, corrupt lines
skipped on read (a crashed writer never poisons history), records from a
*newer* schema skipped with a warning instead of mis-parsed.  Appends are
durable (flush + fsync) and self-healing: a torn final line left by a
crashed writer is repaired before the next record lands, so one crash
costs at most its own record, never a neighbour's.
"""

from __future__ import annotations

import dataclasses
import json
import os
import platform
import subprocess
import sys
import time
import uuid
import warnings
from typing import Any, Iterable, Mapping

from repro.trace.collector import PhaseMeasurement

SCHEMA_VERSION = 1

# phase-payload metric keys every record carries (compare iterates these)
PHASE_METRICS = ("wall_s", "achieved_flops_per_s", "pct_of_roofline",
                 "bound_overlap_s", "bound_serial_s")


def git_sha(repo_root: str | None = None) -> str:
    """HEAD commit of the repo containing this file (or ``repo_root``)."""
    root = repo_root or os.path.dirname(os.path.abspath(__file__))
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=root, capture_output=True,
            text=True, timeout=10)
        if out.returncode == 0:
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


def host_fingerprint() -> dict[str, str]:
    """Where the measurement ran (cross-host comparisons need a warning)."""
    import jax
    return {
        "host": platform.node(),
        "platform": platform.platform(),
        "python": sys.version.split()[0],
        "jax": jax.__version__,
        "backend": jax.default_backend(),
    }


@dataclasses.dataclass
class TraceRecord:
    """One measured run of one config: the unit of storage and comparison."""

    schema_version: int
    run_id: str
    timestamp: float                 # unix seconds
    git_sha: str
    config: str
    machine: str                     # MachineSpec.name the %s are against
    mesh: dict[str, int]             # axis name -> size ({} = single device)
    host: dict[str, str]
    phases: dict[str, dict[str, Any]]   # phase name -> metric payload
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_json(self) -> str:
        # no sort_keys: phase insertion order IS the step order (fwd→bwd→opt)
        # and the timeline re-renders from it
        return json.dumps(dataclasses.asdict(self))

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "TraceRecord":
        """Tolerant constructor: unknown keys dropped, missing keys defaulted
        (older minor revisions of the same schema stay loadable)."""
        fields = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in fields}
        kw.setdefault("schema_version", 0)
        kw.setdefault("run_id", "")
        kw.setdefault("timestamp", 0.0)
        kw.setdefault("git_sha", "unknown")
        kw.setdefault("config", "")
        kw.setdefault("machine", "")
        kw.setdefault("mesh", {})
        kw.setdefault("host", {})
        kw.setdefault("phases", {})
        return cls(**kw)


def phase_payload(m: PhaseMeasurement, top_kernels: int = 8
                  ) -> dict[str, Any]:
    """Serializable per-phase metrics (the record's unit cell).

    Besides the top-``top_kernels`` kernel payloads, the cell keeps three
    whole-phase launch totals computed over *every* kernel (the paper's
    Table III census, per stored phase): total launches, zero-FLOP
    launches, and scatter launches — the ``repro.obs`` advisor mines
    them without re-lowering anything.
    """
    t = m.terms
    launches = sum(k.exec_count for k in m.kernels)
    zero_ai = sum(k.exec_count for k in m.kernels if not k.flops)
    scatter = sum(k.exec_count for k in m.kernels
                  if "scatter" in k.name.lower())
    return {
        "launches": launches,
        "zero_ai_launches": zero_ai,
        "scatter_launches": scatter,
        "wall_s": m.wall_s,
        "iters": m.iters,
        "achieved_flops_per_s": m.achieved_flops_per_s,
        "pct_of_roofline": m.pct_of_roofline,
        "bound_overlap_s": m.bound_overlap_s,
        "bound_serial_s": m.bound_serial_s,
        "compute_s": t.compute_s,
        "memory_s": t.memory_s,
        "collective_s": t.collective_s,
        "dominant": m.dominant,
        "flops": m.flops,
        "hbm_bytes": m.hbm_bytes,
        "vmem_bytes": m.vmem_bytes,
        # interconnect level (third roofline hierarchy level): algorithm-
        # corrected wire bytes split by pod locality + their time bounds
        "ici_bytes": t.ici_wire_bytes,
        "dcn_bytes": t.dcn_wire_bytes,
        "net_bytes": t.ici_wire_bytes + t.dcn_wire_bytes,
        "ici_bound_s": t.collective_ici_s,
        "dcn_bound_s": t.collective_dcn_s,
        "kernels": [
            {"name": k.name, "category": k.category,
             "exec_count": k.exec_count,
             "flops": k.flops, "hbm_bytes": k.hbm_bytes,
             "vmem_bytes": k.vmem_bytes,
             "ai_hbm": k.ai_hbm, "bound_s": k.bound_s,
             "attributed_s": k.attributed_s,
             "achieved_flops_per_s": k.achieved_flops_per_s,
             "pct_of_roofline": k.pct_of_roofline}
            for k in m.kernels[:top_kernels]
        ],
    }


def record_from_payloads(config: str,
                         phases: Mapping[str, Mapping[str, Any]],
                         machine: str,
                         mesh: Mapping[str, int] | None = None,
                         meta: Mapping[str, Any] | None = None) -> TraceRecord:
    """TraceRecord from already-serialized phase payloads.

    The construction path shared by ``record_from_phases`` (live
    measurements) and ``repro.sweep`` (cached / analytical payloads):
    provenance stamping happens in exactly one place.
    """
    return TraceRecord(
        schema_version=SCHEMA_VERSION,
        run_id=uuid.uuid4().hex[:12],
        timestamp=time.time(),
        git_sha=git_sha(),
        config=config,
        machine=machine,
        mesh=dict(mesh or {}),
        host=host_fingerprint(),
        phases={name: dict(p) for name, p in phases.items()},
        meta=dict(meta or {}))


def record_from_phases(config: str,
                       measurements: Mapping[str, PhaseMeasurement],
                       machine: str,
                       mesh: Mapping[str, int] | None = None,
                       meta: Mapping[str, Any] | None = None,
                       top_kernels: int = 8) -> TraceRecord:
    return record_from_payloads(
        config,
        {name: phase_payload(m, top_kernels)
         for name, m in measurements.items()},
        machine=machine, mesh=mesh, meta=meta)


class TraceStore:
    """Append-only JSONL store of :class:`TraceRecord` lines."""

    def __init__(self, path: str):
        self.path = path

    @property
    def _store_kind(self) -> str:
        """Store name fault specs target (``torn_tail:trace`` etc.)."""
        base = os.path.basename(self.path)
        return base[:-len(".jsonl")] if base.endswith(".jsonl") else base

    def append(self, rec: TraceRecord) -> TraceRecord:
        from repro.resilience import faults
        from repro.resilience.jsonl import repair_jsonl_tail
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        repair_jsonl_tail(self.path)
        line = rec.to_json()
        spec = faults.active_plan().fires("torn_tail", self._store_kind)
        if spec is not None:
            # simulate a writer crash mid-append: half the payload, no
            # newline, durably on disk — then die (well, raise)
            with open(self.path, "a") as f:
                f.write(line[:max(1, len(line) // 2)])
                f.flush()
                os.fsync(f.fileno())
            raise faults.InjectedFault(
                f"injected {spec.render()}: torn append to {self.path}")
        with open(self.path, "a") as f:
            f.write(line + "\n")
            f.flush()
            os.fsync(f.fileno())
        return rec

    def records(self, config: str | None = None) -> list[TraceRecord]:
        """All readable records, oldest first; corrupt lines and
        newer-schema records are skipped (with a warning), never fatal."""
        if not os.path.exists(self.path):
            return []
        out: list[TraceRecord] = []
        with open(self.path) as f:
            for i, line in enumerate(f):
                if not line.strip():
                    continue
                try:
                    d = json.loads(line)
                except json.JSONDecodeError:
                    warnings.warn(f"{self.path}:{i+1}: corrupt line skipped")
                    continue
                if d.get("schema_version", 0) > SCHEMA_VERSION:
                    warnings.warn(
                        f"{self.path}:{i+1}: schema "
                        f"{d.get('schema_version')} > {SCHEMA_VERSION} "
                        "(written by newer code) — skipped")
                    continue
                rec = TraceRecord.from_dict(d)
                if config is None or rec.config == config:
                    out.append(rec)
        return out

    def last(self, config: str | None = None, n: int = 1
             ) -> list[TraceRecord]:
        """Last ``n`` records (oldest→newest among those returned)."""
        recs = self.records(config)
        return recs[-n:] if n else []

    def run(self, run_id: str) -> TraceRecord | None:
        for rec in self.records():
            if rec.run_id == run_id or rec.run_id.startswith(run_id):
                return rec
        return None

    def configs(self) -> list[str]:
        seen: dict[str, None] = {}
        for rec in self.records():
            seen.setdefault(rec.config)
        return list(seen)

    def records_where(self, predicate) -> list["TraceRecord"]:
        """Readable records matching ``predicate(rec)``, oldest first
        (e.g. ``lambda r: r.meta.get("sweep") == name``)."""
        return [rec for rec in self.records() if predicate(rec)]


def iter_jsonl(path: str) -> Iterable[dict]:
    """Raw dict view of a store file (debugging / ad-hoc analysis)."""
    with open(path) as f:
        for line in f:
            if line.strip():
                try:
                    yield json.loads(line)
                except json.JSONDecodeError:
                    continue
