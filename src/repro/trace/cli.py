"""``python -m repro.trace`` — record / compare / report measured rooflines.

Subcommands (all sweep any subset of ``repro.configs.registry``):

* ``record``  — build a config's train phases (fwd / bwd / opt), compile
  once, analyze + execute the same executables, and append one
  schema-versioned record per config to the JSONL store:
  measured wall time, achieved GFLOP/s and %-of-roofline per phase,
  bound envelope, top kernels, git SHA + host fingerprint.
* ``compare`` — diff the last two runs per config (or two explicit run
  ids) cell by cell and flag regressions past ``--threshold``; exits
  non-zero when any cell regressed, so CI can gate on it.
* ``report``  — pretty-print the newest stored record per config
  (achieved table + step timeline) without re-running anything.

Examples::

    PYTHONPATH=src python -m repro.trace record --config minitron-4b
    PYTHONPATH=src python -m repro.trace record --all --iters 10
    PYTHONPATH=src python -m repro.trace compare --config minitron-4b
    PYTHONPATH=src python -m repro.trace report
"""

from __future__ import annotations

import argparse
import dataclasses
import sys
import traceback
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import FUSION_MODES, RunConfig, ShapeSpec
from repro.configs.registry import ALL, ARCHS, get_config, get_smoke
from repro.core.machine import MACHINES
from repro.session.workspace import LEGACY_TRACE_STORE, resolve_trace_store
from repro.trace.collector import PhaseMeasurement, collect_phases
from repro.trace.compare import (compare_last, compare_records, format_deltas,
                                 has_regressions)
from repro.trace.store import TraceStore, record_from_phases
from repro.trace.timeline import ascii_timeline, build_timeline, timeline_from_record

# legacy constant (pre-workspace callers import it); the CLI itself
# resolves through repro.session.workspace so REPRO_WORKSPACE governs it
DEFAULT_STORE = LEGACY_TRACE_STORE


# --------------------------------------------------------------------------
# record
# --------------------------------------------------------------------------

def build_phase_args(model, run: RunConfig, *, seq: int = 32, batch: int = 4,
                     seed: int = 0, concrete: bool = True):
    """fwd / bwd / opt phase programs for a built model:
    ``{phase: (fn, args)}`` ready for ``repro.core.profiler`` /
    ``repro.trace.collector``.

    ``concrete=True`` allocates real buffers (the measured path needs them
    anyway); ``concrete=False`` produces ShapeDtypeStruct trees instead —
    the analytical path (``repro.sweep`` campaigns) lowers without
    allocating a single array.
    """
    from repro.models import api as M
    from repro.models.params import init
    from repro.train import optim
    from repro.train.step import make_phases

    cfg = model.cfg
    shape = ShapeSpec("trace", seq, batch, "train")
    fns = make_phases(model, run)
    if concrete:
        params = init(jax.random.PRNGKey(seed), model.spec, run.param_dtype)
        batch_c = M.synthetic_batch(cfg, shape, batch, seed)
        opt_state = optim.optimizer_init(params, run)
    else:
        params = jax.eval_shape(
            lambda k: init(k, model.spec, run.param_dtype),
            jax.random.PRNGKey(seed))
        batch_c = {k: jax.ShapeDtypeStruct(s, dt) for k, (s, dt)
                   in M.batch_schema(cfg, shape, batch).items()}
        opt_state = jax.eval_shape(
            lambda p: optim.optimizer_init(p, run), params)
    grads = jax.tree.map(lambda p: (
        p if isinstance(p, jax.ShapeDtypeStruct) else jnp.zeros_like(p)),
        params)
    return {
        "fwd": (fns["fwd"], (params, batch_c)),
        "bwd": (fns["bwd"], (params, batch_c)),
        "opt": (fns["opt"], (params, grads, opt_state)),
    }


def build_measured_phases(config: str, *, smoke: bool = True, seq: int = 32,
                          batch: int = 4, amp: str = "O1", seed: int = 0,
                          fusion: str = "off",
                          run: RunConfig | None = None):
    """(phases, run): fwd / bwd / opt with *concrete* args, ready to both
    analyze and execute (the measured path needs real buffers anyway)."""
    from repro.models import api as M

    cfg = get_smoke(config) if smoke else get_config(config)
    run = run or RunConfig(amp=amp, fusion=fusion)
    model = M.build(cfg)
    return build_phase_args(model, run, seq=seq, batch=batch,
                            seed=seed), run


def scale_measurement(m: PhaseMeasurement, factor: float) -> PhaseMeasurement:
    """Scale a measurement's wall time (regression drills / tests)."""
    if factor == 1.0:
        return m
    kernels = [dataclasses.replace(
        k, attributed_s=k.attributed_s * factor,
        achieved_flops_per_s=k.achieved_flops_per_s / factor,
        pct_of_roofline=k.pct_of_roofline / factor)
        for k in m.kernels]
    return dataclasses.replace(m, wall_s=m.wall_s * factor, kernels=kernels)


def cmd_record(args) -> int:
    from repro.core.report import achieved_table
    args.store = resolve_trace_store(args.store)
    store = TraceStore(args.store)
    configs = list(ARCHS) if args.all else (args.config or [])
    if not configs:
        print("record: need --config <name> (repeatable) or --all",
              file=sys.stderr)
        return 2
    failures = 0
    for name in configs:
        try:
            phases, run = build_measured_phases(
                name, smoke=not args.full, seq=args.seq, batch=args.batch,
                amp=args.amp, fusion=args.fusion)
            # dot/conv FLOPs classify onto the AMP policy's compute-dtype
            # ceiling (CPU bf16 legalization, docs/DESIGN.md §9) — keeps
            # trace records consistent with repro.sweep / launch.dryrun
            mm_class = ("bf16" if run.compute_dtype == jnp.bfloat16
                        else None)
            ms = collect_phases(phases, machine=args.machine,
                                iters=args.iters, warmup=args.warmup,
                                matmul_class=mm_class)
            if args.scale_wall != 1.0:
                ms = {k: scale_measurement(m, args.scale_wall)
                      for k, m in ms.items()}
            # the fusion mode is part of the record's identity: a fused
            # wall time is only comparable against other fused runs; the
            # kernel_configs stamp is what the tune store offered at
            # measurement time (repro.obs advisor diffs it later)
            from repro.tune import (active_dispatch_table,
                                    active_kernel_configs)
            rec = record_from_phases(
                name, ms, machine=args.machine,
                meta={"smoke": not args.full, "seq": args.seq,
                      "batch": args.batch, "amp": args.amp,
                      "fusion": args.fusion,
                      "scale_wall": args.scale_wall,
                      "kernel_configs": active_kernel_configs(
                          machine=args.machine),
                      "dispatch_table": active_dispatch_table(
                          machine=args.machine)})
            store.append(rec)
        except Exception:
            failures += 1
            print(f"[FAIL] {name}", file=sys.stderr)
            traceback.print_exc()
            continue
        print(f"[{name}] run {rec.run_id} @ {rec.git_sha[:12]} "
              f"-> {args.store}")
        print(achieved_table({name: ms}))
        print(ascii_timeline(build_timeline(ms)))
        print()
    return 1 if failures else 0


# --------------------------------------------------------------------------
# compare / report
# --------------------------------------------------------------------------

def cmd_compare(args) -> int:
    args.store = resolve_trace_store(args.store)
    store = TraceStore(args.store)
    if args.base or args.new:
        if not (args.base and args.new):
            print("compare: --base and --new go together", file=sys.stderr)
            return 2
        base, new = store.run(args.base), store.run(args.new)
        if base is None or new is None:
            print(f"compare: run id not found in {args.store}",
                  file=sys.stderr)
            return 2
        deltas = compare_records(base, new, args.threshold)
    else:
        configs = args.config or [None]
        deltas = []
        for name in configs:
            deltas.extend(compare_last(store, name, args.threshold,
                                       window=args.window))
    print(format_deltas(deltas, only_flagged=not args.all_cells))
    return 1 if has_regressions(deltas) else 0


def cmd_report(args) -> int:
    from repro.core.report import achieved_table
    args.store = resolve_trace_store(args.store)
    store = TraceStore(args.store)
    configs = args.config or store.configs()
    if not configs:
        print(f"report: no records in {args.store}", file=sys.stderr)
        return 2
    status = 0
    for name in configs:
        recs = store.last(name, n=1)
        if not recs:
            print(f"[{name}] no records", file=sys.stderr)
            status = 2
            continue
        rec = recs[0]
        print(f"[{name}] run {rec.run_id} @ {rec.git_sha[:12]} "
              f"machine={rec.machine} host={rec.host.get('host', '?')} "
              f"backend={rec.host.get('backend', '?')}")
        print(achieved_table({name: rec.phases}))
        print(ascii_timeline(timeline_from_record(rec)))
        print()
    return status


# --------------------------------------------------------------------------
# entry point
# --------------------------------------------------------------------------

def _add_store(p) -> None:
    p.add_argument("--store", default=None,
                   help="JSONL store path (default: "
                        f"$REPRO_WORKSPACE/trace.jsonl, else "
                        f"{LEGACY_TRACE_STORE})")


def add_record_parser(sub):
    """``record`` subcommand — shared by ``python -m repro.trace`` and
    the unified ``python -m repro`` CLI (same flags, same cmd)."""
    rec = sub.add_parser("record", help="measure configs, append records")
    rec.add_argument("--config", action="append", choices=list(ALL),
                     help="config name (repeatable)")
    rec.add_argument("--all", action="store_true",
                     help=f"sweep all {len(ARCHS)} assigned archs")
    _add_store(rec)
    rec.add_argument("--machine", default="cpu-host",
                     choices=sorted(MACHINES),
                     help="machine model the %%-of-roofline is against "
                          "(default cpu-host: honest numbers off-TPU)")
    rec.add_argument("--iters", type=int, default=5)
    rec.add_argument("--warmup", type=int, default=2)
    rec.add_argument("--seq", type=int, default=32)
    rec.add_argument("--batch", type=int, default=4)
    rec.add_argument("--amp", default="O1", choices=("O0", "O1", "O2"))
    rec.add_argument("--fusion", default="off", choices=FUSION_MODES,
                     help="fused-kernel routing (repro.kernels.fused); "
                          "stamped into the record's meta so before/after "
                          "traces stay distinguishable")
    rec.add_argument("--full", action="store_true",
                     help="full config instead of the smoke variant")
    rec.add_argument("--scale-wall", type=float, default=1.0,
                     help="multiply measured wall times before storing "
                          "(regression drills / tests)")
    rec.set_defaults(fn=cmd_record)
    return rec


def add_compare_parser(sub):
    cmp_ = sub.add_parser("compare", help="diff runs, flag regressions")
    cmp_.add_argument("--config", action="append",
                      help="restrict to config(s); default: every config "
                           "with >= 2 runs")
    _add_store(cmp_)
    cmp_.add_argument("--base", default=None, help="base run id (prefix ok)")
    cmp_.add_argument("--new", default=None, help="new run id (prefix ok)")
    cmp_.add_argument("--threshold", type=float, default=0.10,
                      help="relative regression threshold (default 0.10)")
    cmp_.add_argument("--window", type=int, default=2,
                      help="compare newest vs (window-1) runs back")
    cmp_.add_argument("--all-cells", action="store_true",
                      help="print every cell, not only flagged ones")
    cmp_.set_defaults(fn=cmd_compare)
    return cmp_


def add_report_parser(sub):
    rep = sub.add_parser("report", help="render the newest stored records")
    rep.add_argument("--config", action="append")
    _add_store(rep)
    rep.set_defaults(fn=cmd_report)
    return rep


def main(argv: Sequence[str] | None = None,
         prog: str = "python -m repro.trace") -> int:
    ap = argparse.ArgumentParser(prog=prog, description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    add_record_parser(sub)
    add_compare_parser(sub)
    add_report_parser(sub)
    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
