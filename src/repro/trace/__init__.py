"""Time-based roofline subsystem: measure, persist, compare.

The analytical pipeline (``repro.core``) answers "how fast *could* this
run"; this package answers "how fast *did* it run, and is it getting
worse":

* :mod:`repro.trace.collector` — execute the same compiled executable the
  analyzer characterized and attribute wall time across kernels by their
  bound-time weights (achieved GFLOP/s, %-of-roofline);
* :mod:`repro.trace.timeline`  — lay measured phases against the
  three-term ``T_compute/T_memory/T_collective`` envelope (overlap model);
* :mod:`repro.trace.store`     — append-only, schema-versioned JSONL
  results store with run provenance (git SHA, host, machine, mesh);
* :mod:`repro.trace.compare`   — per-cell cross-run deltas + regression
  flags;
* :mod:`repro.trace.cli`       — ``python -m repro.trace``
  (record / compare / report) over ``repro.configs.registry``.
"""

from repro.trace.collector import (  # noqa: F401
    KernelMeasurement, PhaseMeasurement, achieved_points, attribute_time,
    collect_phase, collect_phases, kernel_bound_s, measurement_from_profile,
)
from repro.trace.compare import (  # noqa: F401
    CellDelta, compare_last, compare_records, format_deltas, has_regressions,
    regressions,
)
from repro.trace.store import (  # noqa: F401
    PHASE_METRICS, SCHEMA_VERSION, TraceRecord, TraceStore, git_sha,
    host_fingerprint, phase_payload, record_from_payloads,
    record_from_phases,
)
from repro.trace.timeline import (  # noqa: F401
    PhaseSpan, Timeline, ascii_timeline, build_timeline, timeline_from_record,
)
