"""Measured-time collection: fold wall time into the analytical roofline.

The analytical pipeline (``repro.core``) bounds each kernel's time from
below (FLOPs/ceiling, bytes/bandwidth).  This module runs the *same
compiled executable* — ``profile_fn(measure=True)``, never a re-jit — and
spreads the measured wall time across kernels proportionally to their
analytical bound times.  That profile-weighted attribution is the standard
move of the time-based roofline (arXiv 2009.04598): it turns one wall-time
number plus the per-kernel characterization into

* per-kernel *achieved* FLOP/s  = FLOPs / attributed time,
* per-kernel %-of-roofline      = bound time / attributed time,
* per-phase  achieved FLOP/s and %-of-roofline against the three-term
  ``max(T_compute, T_memory, T_collective)`` envelope.

On real TPU hardware the wall time is device time; in the CPU container it
is host time against the ``cpu-host`` machine model — the full
measure→characterize→compare loop is exercised either way.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Sequence

from repro.core.hlo_analysis import KernelRecord, ModuleAnalysis
from repro.core.machine import MachineSpec, get_machine
from repro.core.profiler import ProfileResult, profile_fn
from repro.core.roofline import RooflineTerms, kernel_points


@dataclasses.dataclass(frozen=True)
class KernelMeasurement:
    """One kernel with measured time attributed onto its analytical bound."""

    name: str
    category: str
    exec_count: int
    flops: float                    # total FLOPs (x exec_count)
    hbm_bytes: float                # total fusion-boundary traffic
    ai_hbm: float                   # arithmetic intensity at HBM
    bound_s: float                  # analytical lower bound on time
    attributed_s: float             # share of the measured wall time
    achieved_flops_per_s: float     # flops / attributed_s
    pct_of_roofline: float          # bound_s / attributed_s  (1.0 = at bound)
    vmem_bytes: float = 0.0         # total internal (VMEM-level) traffic


@dataclasses.dataclass
class PhaseMeasurement:
    """One profiled-and-measured phase (fwd / bwd / opt / step)."""

    name: str
    wall_s: float                   # measured median step time
    iters: int
    machine: str
    terms: RooflineTerms            # the analytical three-term envelope
    kernels: list[KernelMeasurement]
    flops: float                    # per-device HLO FLOPs
    hbm_bytes: float
    vmem_bytes: float = 0.0         # per-device internal (VMEM-level) bytes

    @property
    def achieved_flops_per_s(self) -> float:
        return self.flops / self.wall_s if self.wall_s else 0.0

    @property
    def pct_of_roofline(self) -> float:
        """Measured efficiency vs the perfect-overlap bound (<=1 in theory;
        >1 means the machine model under-estimates this host)."""
        return self.terms.bound_overlap_s / self.wall_s if self.wall_s else 0.0

    @property
    def bound_overlap_s(self) -> float:
        return self.terms.bound_overlap_s

    @property
    def bound_serial_s(self) -> float:
        return self.terms.bound_serial_s

    @property
    def dominant(self) -> str:
        return self.terms.dominant

    def summary(self) -> str:
        return (f"[{self.name}] wall {self.wall_s*1e3:.3f} ms | "
                f"achieved {self.achieved_flops_per_s/1e9:.2f} GFLOP/s | "
                f"{100*self.pct_of_roofline:.1f}% of roofline | "
                f"bound [{self.bound_overlap_s*1e3:.3f}, "
                f"{self.bound_serial_s*1e3:.3f}] ms | "
                f"dominant={self.dominant}")


def kernel_bound_s(rec: KernelRecord, machine: MachineSpec) -> float:
    """Analytical time bound for one kernel: the larger of its HBM-roofline
    bound and its pure memory-streaming time (the weighting
    ``repro.core.report.kernel_table`` ranks by)."""
    pts = kernel_points(rec, machine)
    hbm = next(p for p in pts if p.level == "hbm")
    t = hbm.time_bound_s * rec.exec_count
    t_mem = rec.total_hbm_bytes / machine.hbm.bytes_per_s
    return max(t, t_mem)


def attribute_time(analysis: ModuleAnalysis, machine: MachineSpec,
                   wall_s: float) -> list[KernelMeasurement]:
    """Spread measured wall time over kernels by bound-time weight.

    Kernels with zero analytical bound (empty fusions) get zero attributed
    time; if *every* bound is zero the time is split evenly so nothing is
    silently dropped.  Returned sorted by attributed time, descending.
    """
    recs = list(analysis.kernels)
    if not recs:
        return []
    bounds = [kernel_bound_s(r, machine) for r in recs]
    total = sum(bounds)
    out = []
    for rec, bound in zip(recs, bounds):
        weight = bound / total if total else 1.0 / len(recs)
        t_attr = wall_s * weight
        out.append(KernelMeasurement(
            name=rec.name, category=rec.category,
            exec_count=rec.exec_count,
            flops=rec.total_flops, hbm_bytes=rec.total_hbm_bytes,
            ai_hbm=rec.total_flops / rec.total_hbm_bytes
            if rec.total_hbm_bytes else 0.0,
            bound_s=bound, attributed_s=t_attr,
            achieved_flops_per_s=rec.total_flops / t_attr if t_attr else 0.0,
            pct_of_roofline=bound / t_attr if t_attr else 0.0,
            vmem_bytes=rec.total_vmem_bytes))
    out.sort(key=lambda k: -k.attributed_s)
    return out


def achieved_points(kernels: Sequence[KernelMeasurement]
                    ) -> list[tuple[float, float]]:
    """(AI, achieved FLOP/s) scatter for the measured roofline chart."""
    return [(k.ai_hbm, k.achieved_flops_per_s) for k in kernels
            if k.ai_hbm > 0 and k.achieved_flops_per_s > 0]


def measurement_from_profile(res: ProfileResult,
                             machine: MachineSpec | str
                             ) -> PhaseMeasurement:
    """Build a PhaseMeasurement from an already-measured ProfileResult."""
    if isinstance(machine, str):
        machine = get_machine(machine)
    if res.wall_s is None:
        raise ValueError(
            f"{res.name}: ProfileResult has no wall_s — profile with "
            "measure=True (or time_compiled the same executable) first")
    return PhaseMeasurement(
        name=res.name, wall_s=res.wall_s, iters=res.measure_iters,
        machine=machine.name, terms=res.terms,
        kernels=attribute_time(res.analysis, machine, res.wall_s),
        flops=res.analysis.total_flops,
        hbm_bytes=res.analysis.total_hbm_bytes,
        vmem_bytes=res.analysis.total_vmem_bytes)


def collect_phase(name: str, fn: Callable, args: Sequence[Any], *,
                  machine: MachineSpec | str = "cpu-host",
                  iters: int = 10, warmup: int = 3,
                  concrete_args: Sequence[Any] | None = None,
                  **profile_kw) -> PhaseMeasurement:
    """Compile once, analyze + execute that executable, attribute the time."""
    if isinstance(machine, str):
        machine = get_machine(machine)
    res = profile_fn(fn, args=args, name=name, machine=machine,
                     measure=True, measure_iters=iters,
                     measure_warmup=warmup, concrete_args=concrete_args,
                     **profile_kw)
    return measurement_from_profile(res, machine)


def collect_phases(phases: Mapping[str, tuple[Callable, Sequence[Any]]], *,
                   machine: MachineSpec | str = "cpu-host",
                   iters: int = 10, warmup: int = 3,
                   concrete_args: Mapping[str, Sequence[Any]] | None = None,
                   **profile_kw) -> dict[str, PhaseMeasurement]:
    """Measure fwd / bwd / optimizer separately (paper Figs 3-7, measured)."""
    out = {}
    for name, (fn, args) in phases.items():
        conc = concrete_args.get(name) if concrete_args else None
        out[name] = collect_phase(name, fn, args, machine=machine,
                                  iters=iters, warmup=warmup,
                                  concrete_args=conc, **profile_kw)
    return out
