"""Cross-run regression detection over the trace store.

``compare_records`` diffs two :class:`~repro.trace.store.TraceRecord`\\ s
cell by cell — a *cell* is (phase × metric) — and flags any move past a
relative threshold in the bad direction (wall time up, achieved FLOP/s or
%-of-roofline down).  ``compare_last`` wires that to the store's history
so CI can run ``record`` then ``compare`` on every commit and fail the
build when a config gets slower.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

from repro.trace.store import TraceRecord, TraceStore

# metric -> +1 (higher is worse) / -1 (lower is worse)
DEFAULT_METRICS: dict[str, int] = {
    "wall_s": +1,
    "achieved_flops_per_s": -1,
    "pct_of_roofline": -1,
}


@dataclasses.dataclass(frozen=True)
class CellDelta:
    """One (config × phase × metric) comparison between two runs."""

    config: str
    phase: str
    metric: str
    base: float
    new: float
    direction: int                  # +1 higher-is-worse, -1 lower-is-worse
    threshold: float
    base_run: str
    new_run: str

    @property
    def rel_delta(self) -> float:
        """Signed relative change, positive = got worse."""
        if self.base == 0:
            return 0.0 if self.new == 0 else float("inf") * self.direction
        return self.direction * (self.new - self.base) / abs(self.base)

    @property
    def regression(self) -> bool:
        return self.rel_delta > self.threshold

    @property
    def improvement(self) -> bool:
        return self.rel_delta < -self.threshold


def compare_records(base: TraceRecord, new: TraceRecord,
                    threshold: float = 0.10,
                    metrics: Mapping[str, int] | None = None
                    ) -> list[CellDelta]:
    """Per-cell deltas for every phase the two runs share.

    Phases present in only one run are reported as a ``wall_s`` cell with
    the missing side at 0 — a vanished or new phase is itself a signal.
    """
    metrics = dict(metrics or DEFAULT_METRICS)
    out: list[CellDelta] = []
    shared = [p for p in base.phases if p in new.phases]
    for phase in shared:
        b, n = base.phases[phase], new.phases[phase]
        for metric, direction in metrics.items():
            if metric not in b or metric not in n:
                continue
            out.append(CellDelta(
                config=new.config or base.config, phase=phase,
                metric=metric, base=float(b[metric]), new=float(n[metric]),
                direction=direction, threshold=threshold,
                base_run=base.run_id, new_run=new.run_id))
    for phase in base.phases:
        if phase not in new.phases:
            # direction=-1: the drop from base to 0 must read as a
            # regression (a silently dropped phase passing CI is the exact
            # failure mode this gate exists for)
            out.append(CellDelta(
                config=base.config, phase=phase, metric="wall_s",
                base=float(base.phases[phase].get("wall_s", 0.0)), new=0.0,
                direction=-1, threshold=threshold,
                base_run=base.run_id, new_run=new.run_id))
    for phase in new.phases:
        if phase not in base.phases:
            out.append(CellDelta(
                config=new.config, phase=phase, metric="wall_s",
                base=0.0, new=float(new.phases[phase].get("wall_s", 0.0)),
                direction=+1, threshold=threshold,
                base_run=base.run_id, new_run=new.run_id))
    return out


def compare_last(store: TraceStore, config: str | None = None,
                 threshold: float = 0.10, window: int = 2
                 ) -> list[CellDelta]:
    """Compare the newest run of each config against the run ``window - 1``
    records earlier (default: the previous one).

    Runs are grouped by (config, fusion mode): a ``fusion="auto"`` trace
    is a different lowering, not a regression or an improvement of the
    reference one — interleaved before/after records (the documented
    ``record`` / ``record --fusion auto`` pair) must never be diffed
    against each other.
    """
    groups: dict[tuple[str, str], list[TraceRecord]] = {}
    for rec in store.records(config):       # one pass over the store
        key = (rec.config, str(rec.meta.get("fusion", "off")))
        groups.setdefault(key, []).append(rec)
    out: list[CellDelta] = []
    for recs in groups.values():
        recs = recs[-window:]
        if len(recs) < 2:
            continue
        out.extend(compare_records(recs[0], recs[-1], threshold))
    return out


def regressions(deltas: Sequence[CellDelta]) -> list[CellDelta]:
    return [d for d in deltas if d.regression]


def has_regressions(deltas: Sequence[CellDelta]) -> bool:
    return any(d.regression for d in deltas)


def format_deltas(deltas: Sequence[CellDelta],
                  only_flagged: bool = False) -> str:
    """Terminal table, one row per cell; ``!`` = regression, ``+`` =
    improvement past the threshold."""
    rows = [d for d in deltas if not only_flagged
            or d.regression or d.improvement]
    if not rows:
        return "no cells to compare (need >= 2 runs per config)"
    out = [f"{'config':<24}{'phase':<12}{'metric':<22}{'base':>12}"
           f"{'new':>12}{'delta':>9}  flag"]
    for d in rows:
        rel = d.rel_delta
        flag = "!" if d.regression else ("+" if d.improvement else "")
        rel_s = "inf" if rel == float("inf") else f"{100*rel:+.1f}%"
        out.append(
            f"{d.config[:23]:<24}{d.phase[:11]:<12}{d.metric:<22}"
            f"{_fmt(d.base):>12}{_fmt(d.new):>12}{rel_s:>9}  {flag}")
    n_reg = sum(1 for d in rows if d.regression)
    n_imp = sum(1 for d in rows if d.improvement)
    out.append(f"{len(rows)} cells | {n_reg} regression(s) "
               f"| {n_imp} improvement(s) "
               f"(threshold {100*rows[0].threshold:.0f}%, "
               "delta sign: positive = worse)")
    return "\n".join(out)


def _fmt(x: float) -> str:
    if x == 0:
        return "0"
    if abs(x) >= 1e9:
        return f"{x/1e9:.2f}G"
    if abs(x) >= 1e6:
        return f"{x/1e6:.2f}M"
    if abs(x) >= 1e3:
        return f"{x/1e3:.2f}K"
    if abs(x) < 0.1:
        return f"{x*1e3:.3f}m"
    return f"{x:.3f}"
