"""Per-phase timeline + overlap model against the three-term envelope.

Each measured phase carries its analytical envelope from
``repro.core.roofline``::

    bound_overlap_s = max(T_compute, T_memory, T_collective)   (perfect overlap)
    bound_serial_s  = T_compute + T_memory + T_collective      (no overlap)

A measured wall time landing inside ``[overlap, serial]`` tells you how
much overlap the runtime actually achieved (1.0 = perfect, 0.0 = fully
serialized); outside the envelope it tells you the machine model is wrong
for this host (``sub-bound``) or that non-roofline overhead dominates
(``overhead``).  The timeline lays phases out sequentially — a training
step *is* fwd → bwd → opt — and renders a text gantt with the envelope
tick marks on every bar.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

from repro.trace.collector import PhaseMeasurement


@dataclasses.dataclass(frozen=True)
class PhaseSpan:
    """One phase placed on the step timeline."""

    name: str
    start_s: float
    measured_s: float
    bound_overlap_s: float
    bound_serial_s: float
    dominant: str

    @property
    def end_s(self) -> float:
        return self.start_s + self.measured_s

    @property
    def overlap_efficiency(self) -> float:
        """Where the measurement lands inside the envelope.

        1.0 = at the perfect-overlap bound, 0.0 = fully serialized (or
        worse); clamped so out-of-envelope measurements stay readable.
        """
        lo, hi = self.bound_overlap_s, self.bound_serial_s
        if self.measured_s <= lo:
            return 1.0
        if hi <= lo or self.measured_s >= hi:
            return 0.0
        return (hi - self.measured_s) / (hi - lo)

    @property
    def verdict(self) -> str:
        if self.measured_s < self.bound_overlap_s:
            return "sub-bound"          # machine model underestimates host
        if self.measured_s <= self.bound_serial_s:
            return "overlapped"
        if self.measured_s <= 2 * self.bound_serial_s:
            return "serial"
        return "overhead"               # way past even the no-overlap bound


@dataclasses.dataclass
class Timeline:
    spans: list[PhaseSpan]

    @property
    def total_measured_s(self) -> float:
        return sum(s.measured_s for s in self.spans)

    @property
    def total_bound_overlap_s(self) -> float:
        return sum(s.bound_overlap_s for s in self.spans)

    @property
    def total_bound_serial_s(self) -> float:
        return sum(s.bound_serial_s for s in self.spans)

    @property
    def pct_of_roofline(self) -> float:
        t = self.total_measured_s
        return self.total_bound_overlap_s / t if t else 0.0


def build_timeline(measurements: Mapping[str, PhaseMeasurement]) -> Timeline:
    """Sequential layout in mapping order (fwd → bwd → opt)."""
    spans: list[PhaseSpan] = []
    t = 0.0
    for name, m in measurements.items():
        spans.append(PhaseSpan(
            name=name, start_s=t, measured_s=m.wall_s,
            bound_overlap_s=m.bound_overlap_s,
            bound_serial_s=m.bound_serial_s,
            dominant=m.dominant))
        t += m.wall_s
    return Timeline(spans)


def timeline_from_record(rec) -> Timeline:
    """Timeline from a stored :class:`~repro.trace.store.TraceRecord`
    (or anything with a ``.phases`` mapping of metric payloads)."""
    spans: list[PhaseSpan] = []
    t = 0.0
    for name, p in rec.phases.items():
        wall = float(p.get("wall_s", 0.0))
        spans.append(PhaseSpan(
            name=name, start_s=t, measured_s=wall,
            bound_overlap_s=float(p.get("bound_overlap_s", 0.0)),
            bound_serial_s=float(p.get("bound_serial_s", 0.0)),
            dominant=str(p.get("dominant", ""))))
        t += wall
    return Timeline(spans)


def ascii_timeline(tl: Timeline, width: int = 60) -> str:
    """Text gantt: one bar per phase, ``|`` = perfect-overlap bound,
    ``:`` = serial bound, scaled to the whole measured step."""
    total = tl.total_measured_s or 1.0
    scale = width / total
    out = [f"{'phase':<12}{'measured':>11}{'bound[ov,ser]':>18}"
           f"{'overlap':>9}  verdict"]
    for s in tl.spans:
        out.append(
            f"{s.name[:11]:<12}{s.measured_s*1e3:>9.3f}ms"
            f"{s.bound_overlap_s*1e3:>8.3f}/{s.bound_serial_s*1e3:<8.3f}"
            f"{100*s.overlap_efficiency:>8.1f}%  {s.verdict}")
    out.append("")
    for s in tl.spans:
        off = int(s.start_s * scale)
        bar = max(1, int(s.measured_s * scale))
        line = [" "] * (off) + ["#"] * bar
        for mark, t_mark in (("|", s.start_s + s.bound_overlap_s),
                             (":", s.start_s + s.bound_serial_s)):
            x = int(t_mark * scale)
            if x < len(line):
                line[x] = mark
            elif x == len(line):
                line.append(mark)
        out.append(f"{s.name[:11]:<12}" + "".join(line))
    out.append(f"{'':<12}0 {'-'*(width-10)} {total*1e3:.3f} ms")
    out.append(f"{'':<12}# measured  | perfect-overlap bound  : serial bound")
    out.append(
        f"step: {tl.total_measured_s*1e3:.3f} ms measured vs "
        f"[{tl.total_bound_overlap_s*1e3:.3f}, "
        f"{tl.total_bound_serial_s*1e3:.3f}] ms bound | "
        f"{100*tl.pct_of_roofline:.1f}% of roofline")
    return "\n".join(out)
