"""``python -m repro`` — the paper's workflow as one CLI.

One entry point over every subsystem, all persisting into one workspace
root (``--workspace`` / ``REPRO_WORKSPACE``; default
``./.repro-workspace`` inside a checkout, ``~/.repro`` elsewhere):

* ``characterize`` — machine model: datasheet ceilings, or measured ERT
  ceilings of this host (tuned-empirical through the workspace tune
  store) — paper §II-A;
* ``profile``      — analytical HLO walk of a registry config (kernel
  table, three-term bound, roofline chart) — paper §II-B;
* ``record``       — measured trace appended to the workspace trace
  store (same flags as the old ``repro.trace record``);
* ``serve``        — continuous-batching serving under a seeded arrival
  trace; prefill/decode recorded as separate phases (``repro.serve``);
* ``report``       — re-render the newest stored records, no re-running;
* ``compare``      — cross-run regression gate (non-zero exit on
  regression);
* ``sweep``        — cross-config campaigns (``run`` / ``report``),
  forwarded to ``repro.sweep`` with the workspace store;
* ``tune``         — kernel autotuning (``search`` / ``show`` /
  ``apply``), forwarded to ``repro.tune`` with the workspace store;
* ``net``          — interconnect roofline level (``characterize`` /
  ``report``): measured collective ceilings into the workspace tune
  store, network-bound mesh-scale rankings (``repro.net``);
* ``trend``        — perf-trend sparklines over stored records +
  harvested ``BENCH_*.json`` (``--gate`` exits non-zero on regression);
* ``advise``       — mine stored records for known bottleneck patterns,
  ranked evidence-cited remediations;
* ``merge``        — union a remote workspace's stores into this one
  (fleet view; dedupe + skip-and-report conflicts).

The old ``python -m repro.trace`` / ``repro.sweep`` / ``repro.tune``
entry points still work (same flags, same output) but are deprecated
delegations to this surface.

Examples::

    PYTHONPATH=src python -m repro characterize --empirical --smoke
    PYTHONPATH=src python -m repro profile --config minitron-4b --charts 1
    PYTHONPATH=src python -m repro record --config minitron-4b --iters 5
    PYTHONPATH=src python -m repro serve --config minitron-4b --requests 16
    PYTHONPATH=src python -m repro report
    PYTHONPATH=src python -m repro compare --config minitron-4b
    PYTHONPATH=src python -m repro sweep run --smoke
    PYTHONPATH=src python -m repro tune search --smoke
    PYTHONPATH=src python -m repro net characterize --devices 8 --smoke
    PYTHONPATH=src python -m repro net report
    PYTHONPATH=src python -m repro trend --gate
    PYTHONPATH=src python -m repro advise
    PYTHONPATH=src python -m repro merge /mnt/fleet/hostB/.repro-workspace
"""

from __future__ import annotations

import argparse
import contextlib
import os
import sys
from typing import Sequence

from repro.configs.base import FUSION_MODES
from repro.session.workspace import WORKSPACE_ENV, Workspace

PROG = "python -m repro"

#: workflow order — also the order the subcommands are registered in
SUBCOMMANDS = ("characterize", "profile", "record", "serve", "report",
               "compare", "sweep", "tune", "net", "trend", "advise",
               "merge")


@contextlib.contextmanager
def _workspace_env(root: str):
    """Pin ``REPRO_WORKSPACE`` for the duration of one command so every
    store-default resolution (trace / sweep / tune, including forwarded
    subcommands and spawned sweep workers) lands under one root."""
    prev = os.environ.get(WORKSPACE_ENV)
    os.environ[WORKSPACE_ENV] = root
    try:
        yield
    finally:
        if prev is None:
            os.environ.pop(WORKSPACE_ENV, None)
        else:
            os.environ[WORKSPACE_ENV] = prev


def _session(args):
    from repro.session import Session
    return Session(machine=getattr(args, "machine", "cpu-host"),
                   workspace=Workspace(args.workspace))


# --------------------------------------------------------------------------
# session-backed commands
# --------------------------------------------------------------------------

def cmd_characterize(args) -> int:
    s = _session(args)
    res = s.characterize(empirical=args.empirical, tuned=not args.untuned,
                         smoke=args.smoke)
    print(res.render())
    print()
    print(s.workspace.describe())
    return res.exit_code


def cmd_profile(args) -> int:
    s = _session(args)
    from repro.session.session import TRAIN_PHASES
    try:
        res = s.profile(args.config,
                        phases=tuple(args.phase or TRAIN_PHASES),
                        seq=args.seq, batch=args.batch, amp=args.amp,
                        fusion=args.fusion, smoke=not args.full,
                        measure=args.measure, iters=args.iters,
                        warmup=args.warmup)
    except KeyError as e:
        # unknown registry config: message + exit 2, not a traceback —
        # same convention as repro.sweep / benchmarks.run
        print(f"profile: {e.args[0] if e.args else e}", file=sys.stderr)
        return 2
    print(res.render(charts=args.charts, top_kernels=args.top))
    return res.exit_code


def cmd_serve(args) -> int:
    s = _session(args)
    try:
        res = s.serve(args.config, n_requests=args.requests,
                      trace=args.trace, rate=args.rate, burst=args.burst,
                      seed=args.seed, n_slots=args.slots,
                      max_len=args.max_len,
                      prefill_chunk=args.prefill_chunk,
                      page_size=args.page_size, amp=args.amp,
                      fusion=args.fusion, smoke=not args.full,
                      max_ticks=args.max_ticks)
    except KeyError as e:
        print(f"serve: {e.args[0] if e.args else e}", file=sys.stderr)
        return 2
    except ValueError as e:             # non-servable family, bad trace
        print(f"serve: {e}", file=sys.stderr)
        return 2
    print(res.render())
    return res.exit_code


def cmd_trend(args) -> int:
    s = _session(args)
    if args.action == "tag":
        if not args.name:
            print("trend tag: a tag name is required "
                  f"(`{PROG} trend tag NAME [--run RUN_ID]`)",
                  file=sys.stderr)
            return 2
        try:
            res = s.trend_tag(args.name, run_id=args.run)
        except LookupError as e:
            print(f"trend tag: {e}", file=sys.stderr)
            return 2
        print(res.render())
        return res.exit_code
    res = s.trend(config=args.config, gate=args.gate,
                  tolerance=args.tolerance, baseline=args.baseline,
                  max_rows=args.max_rows,
                  bench_dirs=args.bench_dir or None)
    print(res.render())
    return res.exit_code


def cmd_advise(args) -> int:
    s = _session(args)
    res = s.advise(config=args.config, top=args.top)
    print(res.render())
    return res.exit_code


def cmd_merge(args) -> int:
    s = _session(args)
    try:
        res = s.merge(args.remote)
    except FileNotFoundError as e:
        # missing remote root: message + exit 2, same convention as the
        # other subcommands' user errors
        print(f"merge: {e}", file=sys.stderr)
        return 2
    print(res.render())
    return res.exit_code


# record / compare / report share repro.trace.cli's parsers and cmd
# functions verbatim (same flags, same output); the workspace pin above
# makes their default --store land in the workspace.

def _record_with_header(inner):
    """After a successful unified ``record`` into the workspace store,
    refresh the shared machine-provenance header."""
    def run(args) -> int:
        rc = inner(args)            # resolves args.store as a side effect
        ws = Workspace(args.workspace)
        if rc == 0 and os.path.dirname(
                os.path.abspath(args.store)) == ws.root:
            ws.write_header(args.machine)
        return rc
    return run


def _forward(module_main, rest: Sequence[str], prog: str) -> int:
    """Run a sub-CLI's ``main`` on forwarded argv, normalizing SystemExit
    (argparse ``--help``/errors) into a return code."""
    rest = list(rest)
    if rest and rest[0] == "--":            # `repro sweep -- run ...` style
        rest = rest[1:]
    try:
        return int(module_main(rest, prog=prog) or 0)
    except SystemExit as e:                 # argparse --help / usage error
        return int(e.code or 0)


def _forward_subsystem(name: str, rest: Sequence[str]) -> int:
    if name == "sweep":
        from repro.sweep.cli import main as sub_main
    elif name == "net":
        from repro.net.cli import main as sub_main
    else:
        from repro.tune.cli import main as sub_main
    return _forward(sub_main, rest, f"{PROG} {name}")


def _extract_workspace(argv: list[str]) -> tuple[str | None, list[str]]:
    """Pull ``--workspace DIR`` / ``--workspace=DIR`` out of argv wherever
    it appears (before or after the subcommand).  The forwarding fast
    path can't rely on argparse for this: REMAINDER drops a leading
    optional like ``--help`` (bpo-17050), and the forwarded sub-CLIs
    don't know the flag."""
    ws, out, i = None, [], 0
    while i < len(argv):
        a = argv[i]
        if a.startswith("--workspace="):
            ws = a.split("=", 1)[1]
        elif a == "--workspace" and i + 1 < len(argv):
            ws = argv[i + 1]
            i += 1
        else:
            out.append(a)
        i += 1
    return ws, out


# --------------------------------------------------------------------------
# parser
# --------------------------------------------------------------------------

def build_parser() -> argparse.ArgumentParser:
    from repro.core.machine import MACHINES

    ap = argparse.ArgumentParser(
        prog=PROG, description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--workspace", default=None, metavar="DIR",
                    help="workspace root for every store (default: "
                         "$REPRO_WORKSPACE, else ./.repro-workspace in a "
                         "checkout, else ~/.repro)")
    sub = ap.add_subparsers(dest="cmd", required=True)

    def _add_workspace(p) -> None:
        # also accepted after the subcommand (same dest as the top-level
        # flag; SUPPRESS keeps the subparser from clobbering a value the
        # top-level flag already set)
        p.add_argument("--workspace", default=argparse.SUPPRESS,
                       metavar="DIR", help=argparse.SUPPRESS)

    ch = sub.add_parser("characterize",
                        help="machine model: datasheet or measured ERT "
                             "ceilings (paper §II-A)")
    _add_workspace(ch)
    ch.add_argument("--machine", default="cpu-host",
                    choices=sorted(MACHINES),
                    help="machine model to start from (default cpu-host)")
    ch.add_argument("--empirical", action="store_true",
                    help="measure this host's ceilings (ERT micro-kernels) "
                         "instead of the datasheet numbers")
    ch.add_argument("--untuned", action="store_true",
                    help="single default-sample measurements instead of "
                         "best-of-tuned winners from the workspace tune "
                         "store")
    ch.add_argument("--smoke", action="store_true",
                    help="tiny shapes/spaces (CI preset)")
    ch.set_defaults(fn=cmd_characterize)

    pr = sub.add_parser("profile",
                        help="analytical HLO walk of a registry config "
                             "(paper §II-B)")
    _add_workspace(pr)
    pr.add_argument("--config", required=True,
                    help="registry config name (see repro.configs)")
    pr.add_argument("--machine", default="cpu-host",
                    choices=sorted(MACHINES),
                    help="machine model the bounds are against")
    pr.add_argument("--phase", action="append",
                    choices=("fwd", "bwd", "opt"),
                    help="phase to profile (repeatable; default all three)")
    pr.add_argument("--seq", type=int, default=32)
    pr.add_argument("--batch", type=int, default=4)
    pr.add_argument("--amp", default="O1", choices=("O0", "O1", "O2"))
    pr.add_argument("--fusion", default="off", choices=FUSION_MODES)
    pr.add_argument("--full", action="store_true",
                    help="full config instead of the smoke variant")
    pr.add_argument("--measure", action="store_true",
                    help="also execute the same compiled executables and "
                         "fold wall time in (not persisted; use `record`)")
    pr.add_argument("--iters", type=int, default=5)
    pr.add_argument("--warmup", type=int, default=2)
    pr.add_argument("--charts", type=int, default=0,
                    help="render up to N per-phase roofline charts")
    pr.add_argument("--top", type=int, default=10,
                    help="kernel-table rows per phase")
    pr.set_defaults(fn=cmd_profile)

    from repro.trace.cli import (add_compare_parser, add_record_parser,
                                 add_report_parser)
    rec = add_record_parser(sub)
    rec.set_defaults(fn=_record_with_header(rec.get_default("fn")))
    rep = add_report_parser(sub)
    cmp_ = add_compare_parser(sub)
    # the shared trace parsers gain --workspace only on the unified
    # surface; the legacy `python -m repro.trace` flags stay unchanged
    for p in (rec, rep, cmp_):
        _add_workspace(p)

    sv = sub.add_parser("serve",
                        help="continuous-batching serving under a seeded "
                             "arrival trace; prefill/decode recorded as "
                             "separate phases (repro.serve)")
    _add_workspace(sv)
    sv.add_argument("--config", required=True,
                    help="registry config name (dense/moe families)")
    sv.add_argument("--machine", default="cpu-host",
                    choices=sorted(MACHINES),
                    help="machine model the bounds are against")
    sv.add_argument("--requests", type=int, default=16,
                    help="arrival-trace length (default 16)")
    sv.add_argument("--trace", default="poisson",
                    choices=("poisson", "bursty"),
                    help="arrival process (default poisson)")
    sv.add_argument("--rate", type=float, default=1.0,
                    help="arrivals (or bursts) per tick (default 1.0)")
    sv.add_argument("--burst", type=int, default=4,
                    help="requests per burst for --trace bursty")
    sv.add_argument("--seed", type=int, default=0,
                    help="workload + weight-init seed (default 0)")
    sv.add_argument("--slots", type=int, default=4,
                    help="concurrent sequence slots (default 4)")
    sv.add_argument("--max-len", type=int, default=64,
                    help="max tokens per sequence incl. prompt")
    sv.add_argument("--prefill-chunk", type=int, default=16,
                    help="prompt tokens prefetched per tick (default 16)")
    sv.add_argument("--page-size", type=int, default=16,
                    help="KV-cache page size in tokens (default 16)")
    sv.add_argument("--amp", default="O1", choices=("O0", "O1", "O2"))
    sv.add_argument("--fusion", default="off", choices=FUSION_MODES)
    sv.add_argument("--full", action="store_true",
                    help="full config instead of the smoke variant")
    sv.add_argument("--max-ticks", type=int, default=4096,
                    help="tick budget before the run is cut off")
    sv.set_defaults(fn=cmd_serve)

    tr = sub.add_parser("trend",
                        help="perf-trend sparklines over stored records "
                             "+ BENCH_*.json; --gate = CI regression "
                             "gate (repro.obs)")
    _add_workspace(tr)
    tr.add_argument("action", nargs="?", choices=("tag",),
                    help="`trend tag NAME [--run ID]` pins a known-good "
                         "run for --baseline gating")
    tr.add_argument("name", nargs="?",
                    help="tag name for `trend tag`")
    tr.add_argument("--run", default=None,
                    help="run id to tag (default: newest trace record)")
    tr.add_argument("--config", default=None,
                    help="restrict trace series to one registry config")
    tr.add_argument("--machine", default="cpu-host",
                    choices=sorted(MACHINES),
                    help="machine model stamped into the result")
    tr.add_argument("--gate", action="store_true",
                    help="exit 1 when any lower-is-better series "
                         "regressed past --tolerance vs its history")
    tr.add_argument("--tolerance", type=float, default=None,
                    help="relative regression tolerance (default 0.25)")
    tr.add_argument("--baseline", default=None, metavar="TAG_OR_RUN",
                    help="pin the gate to a tagged known-good run "
                         "(`trend tag` name or run id) instead of the "
                         "rolling median")
    tr.add_argument("--max-rows", type=int, default=40,
                    help="series rows to print (default 40)")
    tr.add_argument("--bench-dir", action="append", metavar="DIR",
                    help="extra BENCH_*.json dir(s) instead of the "
                         "workspace bench/ default (repeatable)")
    tr.set_defaults(fn=cmd_trend)

    ad = sub.add_parser("advise",
                        help="mine stored records for bottleneck "
                             "patterns; ranked remediations (repro.obs)")
    _add_workspace(ad)
    ad.add_argument("--config", default=None,
                    help="restrict to one registry config")
    ad.add_argument("--machine", default="cpu-host",
                    choices=sorted(MACHINES),
                    help="machine key the tune-store rules check "
                         "(default cpu-host)")
    ad.add_argument("--top", type=int, default=0,
                    help="print only the top N findings (default: all)")
    ad.set_defaults(fn=cmd_advise)

    mg = sub.add_parser("merge",
                        help="union a remote workspace's stores into "
                             "this one (fleet view, repro.obs)")
    _add_workspace(mg)
    mg.add_argument("remote", metavar="REMOTE_ROOT",
                    help="root directory of the workspace to merge in")
    mg.add_argument("--machine", default="cpu-host",
                    choices=sorted(MACHINES),
                    help="machine model stamped into the result")
    mg.set_defaults(fn=cmd_merge)

    # stubs so the top-level --help lists them; actual dispatch happens in
    # main()'s forwarding fast path, never through these parsers
    for name, help_ in (
            ("sweep",
             "cross-config campaigns: run / report (repro.sweep flags)"),
            ("tune",
             "kernel autotuning: search / show / apply (repro.tune flags)"),
            ("net",
             "interconnect level: characterize / report (repro.net "
             "flags)")):
        p = sub.add_parser(name, help=help_, add_help=False)
        p.add_argument("rest", nargs=argparse.REMAINDER,
                       help=f"arguments for `{PROG} {name}` "
                            f"(try `{PROG} {name} --help`)")
    return ap


def main(argv: Sequence[str] | None = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    explicit_ws, rest = _extract_workspace(argv)
    if rest[:1] and rest[0] in ("sweep", "tune", "net"):
        root = Workspace(explicit_ws).root
        with _workspace_env(root):
            return _forward_subsystem(rest[0], rest[1:])
    ap = build_parser()
    args = ap.parse_args(argv)
    root = Workspace(args.workspace).root
    args.workspace = root
    with _workspace_env(root):
        return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
