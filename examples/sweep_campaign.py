"""Run a roofline campaign from Python (the Session API).

The CLI (``python -m repro sweep run``) covers the common cases; this is
the same campaign as a library call — declare a spec, run it through a
:class:`Session`, read the ranked rows back as data — for when a
hillclimb script wants to sweep programmatically (e.g. sweep AMP
policies for one family and keep the rows, not text).

Run: ``PYTHONPATH=src python examples/sweep_campaign.py``
"""

import tempfile

from repro import Session
from repro.sweep.aggregate import (latest_per_point, summary_rows,
                                   sweep_records)
from repro.sweep.spec import SweepSpec

# Declarative campaign: 2 configs x 2 AMP policies, measured on this host.
# Selectors compose: exact names, "family:<fam>", or "all".
spec = SweepSpec(
    name="example",
    configs=("minitron-4b", "mamba2-1.3b"),
    seqs=(16,), batches=(2,), amps=("O0", "O1"),
    meshes=((1, 1),),
    machine="cpu-host",        # honest %-of-roofline off-TPU
    measure=True, smoke=True, iters=2, warmup=1)

with tempfile.TemporaryDirectory() as d:
    s = Session(machine="cpu-host", workspace=d)
    result = s.sweep(spec, workers=0, progress=print)
    sw = result.data
    print(f"\n{sw.n_ok} ok / {sw.n_failed} failed "
          f"/ {len(sw.skipped)} skipped\n")

    # the ranked cross-config table is pre-rendered on the result ...
    print(result.text)

    # ... and the rows behind it are plain dicts, aggregated from the
    # workspace store only — a campaign run elsewhere reports the same
    # way (ship the workspace, not the host)
    recs = latest_per_point(sweep_records(s.workspace.sweep_store,
                                          "example"))
    best = max(summary_rows(recs), key=lambda r: r["pct_of_roofline"])
    print(f"\nbest point: {best['label']} at "
          f"{100 * best['pct_of_roofline']:.1f}% of roofline "
          f"({best['dominant']}-bound)")
