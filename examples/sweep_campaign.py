"""Run a roofline campaign from Python (the `repro.sweep` library API).

The CLI (``python -m repro.sweep run``) covers the common cases; this is
the same campaign as a library call — declare a spec, run it, aggregate —
for when a hillclimb script wants to sweep programmatically (e.g. sweep
AMP policies for one family and keep the ranked rows as data, not text).

Run: ``PYTHONPATH=src python examples/sweep_campaign.py``
"""

import os
import tempfile

from repro.sweep.aggregate import (latest_per_point, render_summary,
                                   summary_rows, sweep_records)
from repro.sweep.engine import run_sweep
from repro.sweep.spec import SweepSpec
from repro.trace.store import TraceStore

# Declarative campaign: 2 configs x 2 AMP policies, measured on this host.
# Selectors compose: exact names, "family:<fam>", or "all".
spec = SweepSpec(
    name="example",
    configs=("minitron-4b", "mamba2-1.3b"),
    seqs=(16,), batches=(2,), amps=("O0", "O1"),
    meshes=((1, 1),),
    machine="cpu-host",        # honest %-of-roofline off-TPU
    measure=True, smoke=True, iters=2, warmup=1)

with tempfile.TemporaryDirectory() as d:
    store_path = os.path.join(d, "sweep.jsonl")
    result = run_sweep(spec, store_path=store_path, workers=0,
                       progress=print)
    print(f"\n{result.n_ok} ok / {result.n_failed} failed "
          f"/ {len(result.skipped)} skipped\n")

    # aggregate from the store only — a campaign run elsewhere reports the
    # same way (ship the JSONL, not the host)
    recs = latest_per_point(sweep_records(TraceStore(store_path), "example"))
    print(render_summary(recs))

    # the rows behind the table are plain dicts: feed a hillclimb with them
    best = max(summary_rows(recs), key=lambda r: r["pct_of_roofline"])
    print(f"\nbest point: {best['label']} at "
          f"{100 * best['pct_of_roofline']:.1f}% of roofline "
          f"({best['dominant']}-bound)")
