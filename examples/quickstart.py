"""Quickstart: the paper's workflow in 30 lines — one Session object.

1. characterize the machine (ERT, paper §II-A),
2. characterize an application (compiled-HLO walk, paper §II-B),
3. read the hierarchical roofline (paper §IV).

Run: ``PYTHONPATH=src python examples/quickstart.py``
"""

import tempfile

from repro import Session

with tempfile.TemporaryDirectory() as d:         # throwaway workspace root
    s = Session(machine="tpu-v5e", workspace=d)

    # -- 1. machine model (datasheet; `s.characterize(empirical=True)`
    #       measures this host's real ceilings through the tune store) ----
    machine = s.characterize().machine
    print(f"machine: {machine.name}, bf16 peak "
          f"{machine.peak_flops['bf16']/1e12:.0f} TFLOP/s, HBM "
          f"{machine.hbm.bytes_per_s/1e9:.0f} GB/s, "
          f"ridge AI = {machine.ridge_point():.0f} FLOPs/byte\n")

    # -- 2. application: profile one training step, phase by phase -------
    result = s.profile("granite-8b", seq=64, batch=4, amp="O1")

    # -- 3. the hierarchical roofline ------------------------------------
    print(result.render(charts=1, top_kernels=10))
    print("\nzero-AI census (paper Table III):",
          result.analyses["bwd"].zero_ai_census())
