"""Quickstart: the paper's workflow in 30 lines.

1. characterize the machine (ERT, paper §II-A),
2. characterize an application (compiled-HLO walk, paper §II-B),
3. read the hierarchical roofline (paper §IV).

Run: ``PYTHONPATH=src python examples/quickstart.py``
"""

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig, ShapeSpec
from repro.configs.registry import get_smoke
from repro.core import ascii_roofline, get_machine, kernel_table, profile_fn
from repro.models import build, input_specs
from repro.models.params import abstract

# -- 1. machine model (datasheet; see benchmarks/ert_ceilings for measured) --
machine = get_machine("tpu-v5e")
print(f"machine: {machine.name}, bf16 peak "
      f"{machine.peak_flops['bf16']/1e12:.0f} TFLOP/s, HBM "
      f"{machine.hbm.bytes_per_s/1e9:.0f} GB/s, "
      f"ridge AI = {machine.ridge_point():.0f} FLOPs/byte\n")

# -- 2. application: profile one training forward+backward ------------------
cfg = get_smoke("granite-8b")            # --arch granite-8b, reduced
model = build(cfg)
run = RunConfig(amp="O1")                # paper §IV-C: conservative AMP
shape = ShapeSpec("quickstart", seq_len=64, global_batch=4, kind="train")

def train_bwd(params, batch):
    return jax.grad(lambda p: model.loss_fn(p, batch, run)[0])(params)

result = profile_fn(
    train_bwd,
    args=(abstract(model.spec), input_specs(cfg, shape)),
    name="granite-8b/bwd", machine=machine)

# -- 3. the hierarchical roofline -------------------------------------------
print(result.summary(), "\n")
print(ascii_roofline(result.analysis.kernels, machine,
                     title="granite-8b smoke, backward pass"))
print()
print(kernel_table(result.analysis, machine, top_n=10))
print("\nzero-AI census (paper Table III):",
      result.analysis.zero_ai_census())
