"""Serving example: continuous-batching engine + the decode roofline.

Serves a batch of prompts through the slot-based engine (more requests
than slots → slot reuse), then lowers the production ``serve_step`` for
the same architecture and prints its roofline terms — the decode cell of
the dry-run grid, on your own model.

Run: ``PYTHONPATH=src python examples/serve_lm.py``
"""

import jax
import numpy as np

from repro.configs.base import RunConfig, ShapeSpec
from repro.configs.registry import get_smoke
from repro.core import get_machine, profile_fn
from repro.models import build, decode_state_specs, input_specs
from repro.models.params import abstract, init
from repro.serve.engine import Engine, Request

cfg = get_smoke("glm4-9b")
run = RunConfig(amp="O1")
model = build(cfg)
params = init(jax.random.PRNGKey(0), model.spec)

# --- serve a request stream (continuous batching) ---------------------------
engine = Engine(cfg, run, params, n_slots=2, max_len=64)
rng = np.random.default_rng(0)
requests = [
    Request(i, rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(3, 9))).astype(np.int32),
            max_new=6)
    for i in range(5)
]
engine.serve(requests)
for r in requests:
    print(f"request {r.uid}: prompt[{len(r.prompt)}] → {r.out}")
assert all(r.done for r in requests)

# --- the decode-cell roofline for this architecture --------------------------
shape = ShapeSpec("serve", seq_len=64, global_batch=4, kind="decode")
state = decode_state_specs(cfg, shape, batch=4)


def serve_step(p, batch, st):
    return model.decode_fn(p, batch, st, run)


res = profile_fn(serve_step,
                 args=(abstract(model.spec), input_specs(cfg, shape), state),
                 name="glm4-9b/serve_step", machine=get_machine("tpu-v5e"))
print("\nserve_step roofline:", res.summary())
print("decode is", res.terms.dominant,
      "-bound (one token amortizes the whole cache read — paper's "
      "low-AI streaming regime)")
