"""End-to-end driver: train the paper's DeepCAM benchmark (§III-B).

Synthetic climate images → DeepLabv3+-style segmentation, full substrate:
data prefetch, AMP O1, async checkpointing, straggler report — then the
per-phase hierarchical roofline of the exact step that was trained
(paper Figs 3-7 on your own run).

Run: ``PYTHONPATH=src python examples/train_deepcam.py [--steps 30]``
"""

import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig
from repro.configs.deepcam import SMOKE_HW
from repro.configs.registry import get_smoke
from repro.core import get_machine, profile_fn, terms_table, zero_ai_table
from repro.data.pipeline import ClimateStream, Prefetcher
from repro.models import build
from repro.models.params import abstract
from repro.train.trainer import Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=30)
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--impl", default="reference",
                choices=("reference", "fused"))
args = ap.parse_args()

cfg = get_smoke("deepcam")
run = RunConfig(amp="O1", impl=args.impl)
model = build(cfg)
stream = ClimateStream(SMOKE_HW, args.batch)

with tempfile.TemporaryDirectory() as ckpt_dir:
    trainer = Trainer(model, run, stream, ckpt_dir=ckpt_dir, ckpt_every=10,
                      lr=1e-3)
    report = trainer.fit(args.steps, log_every=10)
    print(f"\ntrained {report.steps} steps: loss "
          f"{report.losses[0]:.4f} → {report.losses[-1]:.4f}; "
          f"stragglers={len(report.stragglers)}")
    assert report.losses[-1] < report.losses[0]

# --- the paper's per-phase analysis of this exact model --------------------
machine = get_machine("tpu-v5e")
params_abs = abstract(model.spec)
images = jax.ShapeDtypeStruct((args.batch, *SMOKE_HW, 16), jnp.float32)
labels = jax.ShapeDtypeStruct((args.batch, *SMOKE_HW), jnp.int32)


def fwd(p, im, lb):
    return model.loss_fn(p, {"images": im, "labels": lb}, run)[0]


def bwd(p, im, lb):
    return jax.grad(fwd)(p, im, lb)


results = {
    "fwd": profile_fn(fwd, args=(params_abs, images, labels), name="fwd",
                      machine=machine),
    "bwd": profile_fn(bwd, args=(params_abs, images, labels), name="bwd",
                      machine=machine),
}
print("\nthree-term roofline per phase (paper Figs 3-4):")
print(terms_table(results))
print("\nzero-AI census (paper Table III):")
print(zero_ai_table({k: v.analysis.zero_ai_census()
                     for k, v in results.items()}))
