"""Profile YOUR model: the methodology as a 3-line Session call.

Bring any jax function + abstract inputs; ``Session.profile`` returns
the paper's full analysis (hierarchical roofline chart, per-kernel
table, three-term bound) as one :class:`RooflineResult` — then the
*measured* half: ``measure=True`` executes the same compiled executable
and folds the wall time back in (achieved GFLOP/s, %-of-roofline per
kernel).  Shown here on a custom MLP-mixer-ish toy model nobody in the
repo has ever seen — the point is the tool is model-agnostic.

Run: ``PYTHONPATH=src python examples/profile_your_model.py``
"""

import tempfile

import jax
import jax.numpy as jnp

from repro import Session


def my_model(params, x):
    """Your code here — any jax function works."""
    for w1, w2 in params["blocks"]:
        h = jax.nn.gelu(jnp.einsum("btd,df->btf", x, w1))
        x = x + jnp.einsum("btf,fd->btd", h, w2)
        x = x - x.mean(-1, keepdims=True)            # cheap "norm"
        x = x.swapaxes(1, 2)                          # token mixing
        x = x.swapaxes(1, 2)
    return x.sum()


def loss_and_grad(p, x_):
    return jax.grad(my_model)(p, x_)


D, F, L, B, T = 256, 1024, 4, 8, 128
params = {"blocks": [
    (jax.ShapeDtypeStruct((D, F), jnp.bfloat16),
     jax.ShapeDtypeStruct((F, D), jnp.bfloat16)) for _ in range(L)]}
x = jax.ShapeDtypeStruct((B, T, D), jnp.bfloat16)

with tempfile.TemporaryDirectory() as d:
    # ---- the analytical walk: bounds only, no execution ----------------
    s = Session(machine="tpu-v5e", workspace=d)
    res = s.profile(loss_and_grad, args=(params, x), name="my_model/bwd")
    print(res.render(charts=1, top_kernels=8))
    print("\nwhat to do next: the dominant term above is the bottleneck; "
          "kernels hugging the HBM diagonal want fusion (zero-AI census: "
          f"{res.analyses['my_model/bwd'].zero_ai_census()})")

    # ---- the measured path: same compiled executable, now executed -----
    # Off-TPU the honest ceiling set is the host's, so switch the session
    # machine to cpu-host; on real TPU hardware keep the TPU spec and the
    # identical code times the device.
    host = Session(machine="cpu-host", workspace=d)
    res_m = host.profile(loss_and_grad, args=(params, x),
                         name="my_model/bwd", measure=True, iters=5,
                         warmup=2)
    print()
    print(res_m.render(charts=1))              # achieved table + * overlay
    for lv in res_m.levels("my_model/bwd"):
        print(f"  {lv.level}: {lv.bytes/1e6:.1f} MB moved, "
              f"{lv.achieved_bytes_per_s/1e9:.2f} GB/s achieved "
              f"({100*lv.frac_of_peak:.1f}% of the level's bandwidth)")
    print("\npersist it: `host.record(<registry config>)` appends the same "
          "payload to the workspace trace store; `python -m repro compare` "
          "then flags regressions across commits")
