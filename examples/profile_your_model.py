"""Profile YOUR model: the methodology as a 3-line library call.

Bring any jax function + abstract inputs; get the paper's full analysis
(hierarchical roofline chart, per-kernel table, zero-AI census, three-term
bound) — then the *measured* half: ``measure=True`` executes the same
compiled executable and ``repro.trace`` folds the wall time back into the
chart (achieved GFLOP/s, %-of-roofline per kernel).  Shown here on a
custom MLP-mixer-ish toy model nobody in the repo has ever seen — the
point is the tool is model-agnostic.

Run: ``PYTHONPATH=src python examples/profile_your_model.py``
"""

import jax
import jax.numpy as jnp

from repro.core import (achieved_table, ascii_roofline, get_machine,
                        kernel_table, profile_fn)
from repro.trace import achieved_points, measurement_from_profile


def my_model(params, x):
    """Your code here — any jax function works."""
    for w1, w2 in params["blocks"]:
        h = jax.nn.gelu(jnp.einsum("btd,df->btf", x, w1))
        x = x + jnp.einsum("btf,fd->btd", h, w2)
        x = x - x.mean(-1, keepdims=True)            # cheap "norm"
        x = x.swapaxes(1, 2)                          # token mixing
        x = x.swapaxes(1, 2)
    return x.sum()


D, F, L, B, T = 256, 1024, 4, 8, 128
params = {"blocks": [
    (jax.ShapeDtypeStruct((D, F), jnp.bfloat16),
     jax.ShapeDtypeStruct((F, D), jnp.bfloat16)) for _ in range(L)]}
x = jax.ShapeDtypeStruct((B, T, D), jnp.bfloat16)


def loss_and_grad(p, x_):
    return jax.grad(my_model)(p, x_)


machine = get_machine("tpu-v5e")
res = profile_fn(loss_and_grad, args=(params, x), name="my_model/bwd",
                 machine=machine)
print(res.summary())
print()
print(ascii_roofline(res.analysis.kernels, machine, title="my model, bwd"))
print()
print(kernel_table(res.analysis, machine, top_n=8))
print("\nwhat to do next: the dominant term above is the bottleneck; "
      "kernels hugging the HBM diagonal want fusion (zero-AI census: "
      f"{res.analysis.zero_ai_census()})")

# ---- the measured path: same compiled executable, now executed -----------
# Off-TPU the honest ceiling set is the host's, so the achieved/%-roofline
# numbers are reported against the cpu-host machine model; on real TPU
# hardware pass the TPU spec and the identical code times the device.
host = get_machine("cpu-host")
res_m = profile_fn(loss_and_grad, args=(params, x), name="my_model/bwd",
                   machine=host, measure=True, measure_iters=5,
                   measure_warmup=2)
m = measurement_from_profile(res_m, host)
print()
print(m.summary())
print()
print(achieved_table({"my_model": {"bwd": m}}))
print()
print(ascii_roofline(res_m.analysis.kernels, host,
                     title="my model, bwd (measured)",
                     achieved=achieved_points(m.kernels)))
print("\npersist it: repro.trace.TraceStore('trace.jsonl').append("
      "repro.trace.record_from_phases('my_model', {'bwd': m}, "
      "machine='cpu-host')) — then `python -m repro.trace compare` "
      "flags regressions across commits")
